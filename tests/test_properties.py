"""Hypothesis property tests for the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from hypothesis.extra.numpy import arrays

from repro.core import gonzalez, mrg_sim
from repro.kernels import ref

SET = settings(max_examples=25, deadline=None,
               suppress_health_check=[HealthCheck.too_slow])


def point_sets(min_n=8, max_n=64, max_d=5):
    return st.integers(min_n, max_n).flatmap(
        lambda n: st.integers(1, max_d).flatmap(
            lambda d: arrays(np.float32, (n, d),
                             elements=st.floats(-100, 100, width=32))))


@given(pts=point_sets(), k=st.integers(2, 6))
@SET
def test_gonzalez_radius_covers_every_point(pts, k):
    k = min(k, pts.shape[0])
    res = gonzalez(jnp.asarray(pts), k)
    _, d2 = ref.assign_nearest(jnp.asarray(pts), res.centers)
    r2 = float(res.radius2)
    assert float(jnp.max(d2)) <= r2 * (1 + 1e-4) + 1e-2


@given(pts=point_sets(), k=st.integers(2, 6))
@SET
def test_gonzalez_centers_are_input_points(pts, k):
    k = min(k, pts.shape[0])
    res = gonzalez(jnp.asarray(pts), k)
    idx = np.asarray(res.indices)
    assert ((0 <= idx) & (idx < pts.shape[0])).all()
    assert np.allclose(np.asarray(res.centers), pts[idx], atol=1e-6)


@given(pts=point_sets(), k=st.integers(2, 6))
@SET
def test_gonzalez_anti_chain(pts, k):
    # selected centers pairwise separation >= covering radius
    k = min(k, pts.shape[0])
    res = gonzalez(jnp.asarray(pts), k)
    # duplicate input points can yield duplicate centers at radius 0
    pd = np.asarray(ref.pairwise_dist2(res.centers, res.centers))
    pd = pd + np.eye(k) * 1e12
    assert pd.min() >= float(res.radius2) - 1e-3


@given(pts=point_sets(min_n=16), k=st.integers(2, 4),
       m=st.integers(2, 5))
@SET
def test_mrg_within_factor_of_gon(pts, k, m):
    # MRG <= 4·OPT and GON >= OPT  =>  MRG <= 4·GON(+eps)
    g = gonzalez(jnp.asarray(pts), k)
    r = mrg_sim(jnp.asarray(pts), k, m=m, capacity=10_000)
    lhs = float(jnp.sqrt(r.radius2))
    rhs = 4.0 * float(jnp.sqrt(g.radius2))
    assert lhs <= rhs + 1e-3


@given(pts=point_sets(min_n=12), k=st.integers(2, 5))
@SET
def test_permutation_invariance_of_radius_scale(pts, k):
    # covering radius of GON is invariant to point permutation up to the
    # greedy's own seed (first center pinned to index 0) — permuting and
    # re-seeding with the same physical point gives identical radii.
    perm = np.random.default_rng(0).permutation(pts.shape[0])
    k = min(k, pts.shape[0])
    r1 = gonzalez(jnp.asarray(pts), k, first=0)
    where = int(np.nonzero(perm == 0)[0][0])
    r2 = gonzalez(jnp.asarray(pts[perm]), k, first=where)
    assert np.isclose(float(r1.radius2), float(r2.radius2), rtol=1e-4,
                      atol=1e-5)


@given(x=arrays(np.float32, (33, 7),
                elements=st.floats(-50, 50, width=32)),
       c=arrays(np.float32, (9, 7), elements=st.floats(-50, 50, width=32)))
@SET
def test_pairwise_matches_direct(x, c):
    got = np.asarray(ref.pairwise_dist2(jnp.asarray(x), jnp.asarray(c)))
    want = ((x[:, None, :] - c[None, :, :]) ** 2).sum(-1)
    assert np.allclose(got, want, rtol=1e-3, atol=1e-2)


@given(pts=point_sets(min_n=10), frac=st.floats(0.3, 0.9))
@SET
def test_coreset_weights_sum_to_n(pts, frac):
    from repro.core import select_coreset
    k = max(2, int(pts.shape[0] * frac * 0.2))
    cs = select_coreset(jnp.asarray(pts), k)
    assert int(jnp.sum(cs.weights)) == pts.shape[0]
