"""Pallas kernel validation: shape/dtype sweeps against the jnp oracles
(interpret mode on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(8, 8, 2), (100, 17, 3), (256, 64, 64), (1000, 37, 128),
          (513, 9, 5)]
DTYPES = [np.float32, np.float16]


def _data(n, m, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(dtype)
    c = rng.normal(size=(m, d)).astype(dtype)
    md = rng.uniform(0.5, 20, size=(n,)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(c), jnp.asarray(md)


@pytest.mark.parametrize("n,m,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pairwise_dist2(n, m, d, dtype):
    x, c, _ = _data(n, m, d, dtype)
    got = ops.pairwise_dist2(x, c, impl="pallas", bn=64, bm=16)
    want = ref.pairwise_dist2(x, c)
    tol = 1e-4 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("n,m,d", SHAPES)
def test_fused_min_argmax(n, m, d):
    x, c, md = _data(n, m, d, np.float32)
    nm, fv, fi = ops.fused_min_argmax(x, c[0], md, impl="pallas", bn=64)
    nm2, fv2, fi2 = ref.fused_min_argmax(x, c[0], md)
    np.testing.assert_allclose(np.asarray(nm), np.asarray(nm2), rtol=1e-5)
    assert int(fi) == int(fi2)
    np.testing.assert_allclose(float(fv), float(fv2), rtol=1e-5)


@pytest.mark.parametrize("n,m,d", SHAPES)
def test_assign_nearest(n, m, d):
    x, c, _ = _data(n, m, d, np.float32, seed=3)
    ia, da = ops.assign_nearest(x, c, impl="pallas", bn=64, bm=8)
    ib, db = ref.assign_nearest(x, c)
    # ties can legitimately differ; compare distances, then indices where
    # the nearest is unique
    np.testing.assert_allclose(np.asarray(da), np.asarray(db), rtol=1e-4,
                               atol=1e-4)
    d2 = np.asarray(ref.pairwise_dist2(x, c))
    part = np.partition(d2, 1, axis=1)
    unique = part[:, 1] - part[:, 0] > 1e-5
    assert (np.asarray(ia)[unique] == np.asarray(ib)[unique]).all()


def test_padding_rows_never_win():
    # n=5 with block 64 => heavy padding; padded rows must not be argmax
    x, c, md = _data(5, 3, 2, np.float32, seed=4)
    nm, fv, fi = ops.fused_min_argmax(x, c[0], md, impl="pallas", bn=64)
    assert 0 <= int(fi) < 5


def test_impl_auto_selects_ref_on_cpu():
    x, c, _ = _data(16, 4, 2, np.float32)
    a = ops.pairwise_dist2(x, c, impl="auto")
    b = ref.pairwise_dist2(x, c)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
