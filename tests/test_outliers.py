"""(k,z)-center with outliers + the weighted-fold substrate beneath it.

Contracts under test (core/outliers.py + the weighted Objective paths):

  * ``kz_center`` matches the brute-force (k,z) optimum within the
    coreset-then-solve approximation bound at small n, and its streamed
    pipeline never materializes the source;
  * the streamed top-(z+1) fold (``fold_top_k_min_d2`` /
    ``covering_radius_excluding`` / ``radius2(objective=...)``) is exact
    vs the numpy sort oracle for every blocking, source, and impl;
  * unit-weight weighted objectives are *bitwise* the plain programs on
    all three executors (the PR's no-regression contract): same centers,
    same radius bits, for Array / Host / Memmap sources and ragged and
    even blockings alike;
  * weights compose through the source views (WeightedSource wrapped by
    Indexed / Slice / Sharded views) and are conserved by the weighted
    rounds (per-cluster sums total the source weight).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import (HostStreamExecutor, MeshExecutor, Objective,
                        SimExecutor, brute_force_opt_z,
                        covering_radius_excluding, kz_center, mrg,
                        select_coreset)
from repro.core.executor import weighted_gon_block_fn
from repro.data import (ArraySource, HostSource, IndexedSource, MemmapSource,
                        WeightedSource, shard_source, take_weights,
                        weights_of)
from repro.kernels import ops


def _pts(n=640, d=5, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _clustered_with_outliers(n=500, d=3, k=4, z=3, spread=100.0, seed=0):
    """k tight planted clusters + z far-flung outliers."""
    rng = np.random.default_rng(seed)
    cents = rng.normal(size=(k, d)).astype(np.float32) * 10.0
    x = (cents[rng.integers(0, k, size=n)] +
         rng.normal(size=(n, d)).astype(np.float32) * 0.5)
    out = rng.normal(size=(z, d)).astype(np.float32) * 0.1 + spread
    x[:z] = out
    return x.astype(np.float32)


def _one_device_mesh():
    return compat.make_mesh(np.array(jax.devices()[:1]), ("data",))


# ---------------------------------------------------------------------------
# kz_center vs the brute-force (k,z) oracle
# ---------------------------------------------------------------------------

def test_kz_center_within_approximation_bound_of_brute_force():
    """Small-n oracle: coreset-then-solve stays within the paper-family
    bound (coreset construction + 3-approx Charikar ⇒ O(1); we assert a
    conservative 13x with fp slack) and never collapses to the plain
    k-center answer when the outliers are extreme."""
    rng = np.random.default_rng(7)
    x = rng.normal(size=(14, 2)).astype(np.float32)
    x[:2] += 50.0                       # 2 extreme outliers
    k, z = 2, 2
    opt = brute_force_opt_z(x, k, z)
    assert opt > 0.0
    for ex in (SimExecutor(m=3), HostStreamExecutor(block_rows=5)):
        src = x if isinstance(ex, SimExecutor) else HostSource(x)
        res = kz_center(src, k, z, executor=ex, impl="ref")
        r = float(np.sqrt(res.radius2))
        assert r / opt <= 13.0 + 1e-5, (r, opt)
        # the outliers were excluded: the (k,z) radius is far below the
        # plain covering radius the 50-unit outliers would force
        assert r < 25.0


def test_kz_center_excludes_planted_outliers_all_executors():
    x = _clustered_with_outliers(n=500, k=4, z=3)
    for name, ex, src in [
        ("sim", SimExecutor(m=5), x),
        ("host", HostStreamExecutor(block_rows=128), HostSource(x)),
        ("mesh", MeshExecutor(_one_device_mesh(), block_rows=128),
         HostSource(x)),
    ]:
        res = kz_center(src, 4, 3, executor=ex, impl="ref")
        assert res.centers.shape == (4, x.shape[1])
        # outliers sit ~100 away; excluding z of them must leave a small
        # radius (planted clusters have sigma 0.5 around spread-10 means)
        assert float(np.sqrt(res.radius2)) < 30.0, name
        assert res.rounds >= 2


def test_kz_center_z0_reduces_to_plain_objective_value():
    """z=0: the (k,0) objective IS the covering radius — the streamed
    top-1 fold must equal the plain radius fold bitwise for the returned
    centers."""
    x = _pts(300, 3, seed=3)
    res = kz_center(x, 5, 0, m=4, impl="ref")
    _, d2 = ops.assign_nearest(jnp.asarray(x), res.centers, impl="ref")
    assert float(res.radius2) == float(jnp.max(d2))


def test_kz_center_validates_arguments():
    x = _pts(32, 2)
    with pytest.raises(ValueError):
        kz_center(x, 0, 1)
    with pytest.raises(ValueError):
        kz_center(x, 2, -1)
    with pytest.raises(ValueError):
        kz_center(x, 4, 1, t=2)
    with pytest.raises(ValueError):
        Objective(outliers=-1)


def test_kz_center_streams_without_materializing():
    """The R002 contract as a runtime fact: the full streamed pipeline
    (round 1, weighted combine, host solve, top-(z+1) radius fold) never
    pulls all n rows onto the device."""
    class NoMaterialize(HostSource):
        def materialize(self):
            raise AssertionError("kz_center materialized the source")

    x = _clustered_with_outliers(n=400, k=3, z=2)
    src = NoMaterialize(x)
    res = kz_center(src, 3, 2, executor=HostStreamExecutor(block_rows=64),
                    solve_capacity=24, impl="ref")
    assert res.centers.shape == (3, x.shape[1])
    assert res.rounds > 2          # solve_capacity forced extra levels
    r = covering_radius_excluding(NoMaterialize(x), np.asarray(res.centers),
                                  2, block_rows=64)
    assert float(r) ** 2 == pytest.approx(float(res.radius2), rel=1e-6)


# ---------------------------------------------------------------------------
# the streamed top-(z+1) fold vs the numpy sort oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_rows", [256, 999])
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_fold_top_k_min_d2_matches_sort_oracle(block_rows, impl, tmp_path):
    x = _pts(1234, 4, seed=5)
    c = _pts(7, 4, seed=6)
    d2 = np.asarray(ops.assign_nearest(jnp.asarray(x), jnp.asarray(c),
                                       impl="ref")[1])
    order = np.sort(d2)[::-1]
    sources = [ArraySource(x), HostSource(x),
               MemmapSource.save_shards(x, tmp_path / impl,
                                        rows_per_shard=500)]
    for src in sources:
        for z in (0, 1, 5):
            top = ops.fold_top_k_min_d2(src, jnp.asarray(c), z + 1,
                                        impl=impl, block_rows=block_rows)
            # value folds are blocking-invariant: exact, not approx
            np.testing.assert_array_equal(np.asarray(top), order[:z + 1])
            r = covering_radius_excluding(src, c, z, impl=impl,
                                          block_rows=block_rows)
            assert float(r) == float(np.sqrt(np.float32(order[z])))


def test_radius2_objective_consistent_across_executors():
    """Executor.radius2 under an outlier objective: every executor's
    reduction (Sim eager top-k, HostStream/Mesh streamed fold) returns
    the identical top-(z+1) slot."""
    x = _pts(800, 3, seed=8)
    c = _pts(6, 3, seed=9)
    obj = Objective(name="kz_center", weighted=True, outliers=4)
    vals = {
        "sim": SimExecutor(m=4).radius2(x, jnp.asarray(c), impl="ref",
                                        objective=obj),
        "host": HostStreamExecutor(block_rows=300).radius2(
            HostSource(x), jnp.asarray(c), impl="ref", objective=obj),
        "mesh_arr": MeshExecutor(_one_device_mesh(), block_rows=300).radius2(
            ArraySource(x), jnp.asarray(c), impl="ref", objective=obj),
        "mesh_str": MeshExecutor(_one_device_mesh(), block_rows=300).radius2(
            HostSource(x), jnp.asarray(c), impl="ref", objective=obj),
    }
    d2 = np.asarray(ops.assign_nearest(jnp.asarray(x), jnp.asarray(c),
                                       impl="ref")[1])
    want = np.sort(d2)[::-1][4]
    for name, v in vals.items():
        assert float(v) == float(np.float32(want)), name


# ---------------------------------------------------------------------------
# unit-weight weighted folds are bitwise the plain programs (parity grid)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_rows", [256, 999])
def test_unit_weight_parity_grid_streamed_executors(block_rows, tmp_path):
    """The tentpole no-regression contract, streamed half: for Host /
    Memmap sources on HostStream and (single-device) Mesh executors, the
    weighted objective with implicit unit weights reproduces today's
    plain mrg bits — centers, radius2, and rounds."""
    n, d, k = 1234, 3, 5
    x = _pts(n, d, seed=11)
    obj = Objective(weighted=True)
    mesh = _one_device_mesh()
    sources = {
        "host": lambda: HostSource(x),
        "memmap": lambda: MemmapSource.save_shards(
            x, tmp_path / str(block_rows), rows_per_shard=500),
    }
    for sname, mk in sources.items():
        for ename, ex in [
            ("hoststream", HostStreamExecutor(block_rows=block_rows)),
            ("mesh", MeshExecutor(mesh, block_rows=block_rows)),
        ]:
            plain = mrg(mk(), k, executor=ex, impl="ref")
            wres = mrg(mk(), k, executor=ex, impl="ref", objective=obj)
            cell = f"{sname}×{ename}×{block_rows}"
            np.testing.assert_array_equal(np.asarray(plain.centers),
                                          np.asarray(wres.centers), cell)
            assert float(plain.radius2) == float(wres.radius2), cell
            assert plain.rounds == wres.rounds, cell
            assert plain.weights is None
            w = np.asarray(wres.weights)
            assert w.shape == (k,) and float(w.sum()) == float(n), cell


def test_unit_weight_parity_sim_and_mesh_array_source():
    """Device-resident half of the grid: SimExecutor on a raw array, and
    the MeshExecutor's ArraySource weighted fallback — which must match
    the *streamed* plain run of the same blocking (the fused device
    program has no weight operand and is deliberately not taken)."""
    n, d, k = 1234, 3, 5
    x = _pts(n, d, seed=11)
    obj = Objective(weighted=True)
    plain = mrg(x, k, m=7, impl="ref")
    wres = mrg(x, k, m=7, impl="ref", objective=obj)
    np.testing.assert_array_equal(np.asarray(plain.centers),
                                  np.asarray(wres.centers))
    assert float(plain.radius2) == float(wres.radius2)
    assert float(np.asarray(wres.weights).sum()) == float(n)

    mesh = _one_device_mesh()
    ex = MeshExecutor(mesh, block_rows=256)
    wm = mrg(ArraySource(x), k, executor=ex, impl="ref", objective=obj)
    ph = mrg(HostSource(x), k,
             executor=HostStreamExecutor(block_rows=256), impl="ref")
    np.testing.assert_array_equal(np.asarray(wm.centers),
                                  np.asarray(ph.centers))
    # radius differs in *path* (mesh-array evaluates eagerly) but not in
    # value bits: both reduce the same eager-assign d2 multiset
    assert float(wm.radius2) == float(ph.radius2)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_weighted_filter_round_unit_weights_bitwise(impl):
    """run_filter_round with all-ones weights reproduces the plain pivot
    and distance bits (Sim and HostStream; both impls — the pallas cell
    exercises ``fused_filter_blocks_w`` in interpret mode off-TPU), and
    zero weights gate rows out of pivot candidacy exactly like H=False."""
    n, d = 700, 3
    x = _pts(n, d, seed=13)
    s_new = _pts(4, d, seed=14)
    rank = 9
    for ex in (SimExecutor(m=4), HostStreamExecutor(block_rows=256)):
        src = x if isinstance(ex, SimExecutor) else HostSource(x)
        base_d = np.full(n, np.float32(3.4e38), np.float32)
        h = np.ones(n, bool)
        d_plain, piv_plain = ex.run_filter_round(
            src, s_new, base_d.copy(), h, rank, impl=impl)
        d_ones, piv_ones = ex.run_filter_round(
            src, s_new, base_d.copy(), h, rank, impl=impl,
            weights=np.ones(n, np.float32))
        np.testing.assert_array_equal(d_plain, d_ones)
        assert float(piv_plain) == float(piv_ones)
        # zero out the weight of every current top-rank row: the pivot
        # must drop to the best of the remaining support
        order = np.argsort(d_plain)[::-1]
        w = np.ones(n, np.float32)
        w[order[:rank]] = 0.0
        d_gated, piv_gated = ex.run_filter_round(
            src, s_new, d_plain.copy(), h, rank, impl=impl, weights=w)
        np.testing.assert_array_equal(d_gated, d_plain)  # d still updates
        assert float(piv_gated) == float(
            np.float32(np.sort(d_plain)[::-1][2 * rank - 1]))


def test_mesh_filter_round_rejects_weights():
    x = _pts(128, 2)
    ex = MeshExecutor(_one_device_mesh(), block_rows=64)
    with pytest.raises(NotImplementedError):
        ex.run_filter_round(HostSource(x), _pts(2, 2),
                            np.full(128, np.float32(3.4e38), np.float32),
                            np.ones(128, bool), 3, weights=np.ones(128,
                                                                   np.float32))


# ---------------------------------------------------------------------------
# weights through the source views
# ---------------------------------------------------------------------------

def test_weighted_source_composes_through_views():
    x = _pts(200, 2, seed=17)
    w = (np.arange(200) % 5 + 1).astype(np.float32)
    ws = WeightedSource(HostSource(x), w)
    np.testing.assert_array_equal(weights_of(ws, 30, 40), w[30:70])
    # plain sources default to unit weights
    np.testing.assert_array_equal(weights_of(HostSource(x), 0, 10),
                                  np.ones(10, np.float32))
    idx = np.asarray([5, 3, 199, 0])
    np.testing.assert_array_equal(take_weights(ws, idx), w[idx])
    sub = IndexedSource(ws, np.arange(0, 200, 3))
    np.testing.assert_array_equal(weights_of(sub, 2, 4),
                                  w[np.arange(0, 200, 3)][2:6])
    sh = shard_source(ws, 3)
    got = np.concatenate([weights_of(sh, off, 50)
                          for off in (0, 50, 100, 150)])
    np.testing.assert_array_equal(got, w)
    with pytest.raises(ValueError):
        WeightedSource(HostSource(x), w[:-1])
    with pytest.raises(ValueError):
        WeightedSource(HostSource(x), -w)


def test_weighted_rounds_conserve_total_weight():
    """Per-cluster weight sums total the source weight through round 1,
    every combine level, and the final aggregation — f32 adds of integer
    weights are exact here (total << 2^24)."""
    x = _pts(900, 3, seed=19)
    w = (np.arange(900) % 7 + 1).astype(np.float32)
    ws = WeightedSource(HostSource(x), w)
    res = mrg(ws, 4, executor=HostStreamExecutor(block_rows=128),
              capacity=16, impl="ref", objective=Objective(weighted=True))
    assert float(np.asarray(res.weights).sum()) == float(w.sum())
    assert res.rounds > 2          # capacity forced combine levels

    cs = select_coreset(ws, 6, executor=HostStreamExecutor(block_rows=128),
                        impl="ref")
    assert float(np.asarray(cs.weights).sum()) == float(w.sum())


def test_weighted_block_fn_zero_weight_rows_never_selected():
    """Round-1 selection masks out w<=0 rows (they carry no objective
    mass), and their weight contributes nothing to the cluster sums."""
    x = np.zeros((8, 2), np.float32)
    x[0] = (100.0, 100.0)              # far row, weight 0
    x[1:] = _pts(7, 2, seed=23)
    w = np.ones(8, np.float32)
    w[0] = 0.0
    fn = weighted_gon_block_fn(3, "ref", None)
    centers, cw = fn(jnp.asarray(x), jnp.ones(8, bool), jnp.asarray(w))
    assert not np.any(np.all(np.asarray(centers) == x[0], axis=1))
    assert float(np.asarray(cw).sum()) == 7.0
