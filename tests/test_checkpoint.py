"""Checkpoint manager: roundtrip, atomicity, retention, resume-exactness,
fault-tolerant restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import token_batch


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "nested": {"b": jnp.arange(5), "c": jnp.float32(2.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 7
    step, got = restore_checkpoint(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_prunes_old(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    files = [f for f in os.listdir(tmp_path) if f.startswith("ckpt_")]
    assert len(files) == 2
    assert latest_step(str(tmp_path)) == 5


def test_no_tmp_litter(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    with pytest.raises(ValueError, match="mismatch"):
        restore_checkpoint(str(tmp_path), {"different": jnp.zeros(2)})


def test_data_pipeline_resume_exactness():
    # batch at step s is identical regardless of history
    a = token_batch(100, 4, 8, seed=3, step=17)
    b = token_batch(100, 4, 8, seed=3, step=17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = token_batch(100, 4, 8, seed=3, step=18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_train_restart_from_fault(tmp_path):
    """Inject a fault mid-run; the driver must resume from checkpoint and
    converge to the same final step."""
    from repro.launch.train import RestartPolicy, train_loop

    cfg = get_config("olmo_1b", smoke=True)
    boom = {"armed": True}

    def fault(step):
        if step == 7 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected device failure")

    state, hist = train_loop(
        cfg, steps=10, batch_size=4, seq_len=16,
        ckpt_dir=str(tmp_path), ckpt_every=5, resume="auto",
        fault_hook=fault, policy=RestartPolicy(max_restarts=2,
                                               backoff_s=0.01),
        log_every=100)
    steps_seen = [h["step"] for h in hist]
    assert steps_seen[-1] == 9
    assert 5 in steps_seen and 6 in steps_seen  # replay after restart
    assert latest_step(str(tmp_path)) == 10


def test_restart_policy_gives_up():
    from repro.launch.train import train_loop, RestartPolicy
    cfg = get_config("olmo_1b", smoke=True)

    def always_fail(step):
        raise RuntimeError("permanent failure")

    with pytest.raises(RuntimeError, match="permanent"):
        train_loop(cfg, steps=5, batch_size=2, seq_len=8,
                   fault_hook=always_fail,
                   policy=RestartPolicy(max_restarts=2, backoff_s=0.0),
                   log_every=100)


def test_straggler_watchdog_flags_outliers():
    from repro.launch.train import StragglerWatchdog
    w = StragglerWatchdog(factor=3.0, warmup=2)
    for _ in range(6):
        w.observe(0.1)
    assert w.observe(1.0) is True
    assert w.flagged == 1
