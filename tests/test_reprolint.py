"""Fixture suite for tools/reprolint: per rule, at least one minimal
violating snippet (caught, with the correct line) and one conforming
twin (clean), plus suppression-comment round-trips and the CLI contract.

reprolint is pure stdlib, so this file never imports jax — it must pass
on a runner with no jax installed (the CI lint job).
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.reprolint import check_source  # noqa: E402

CORE = "src/repro/core/fixture.py"
ENGINE = "src/repro/kernels/engine.py"


def rules(diags):
    return [d.rule for d in diags]


def lines(diags, rule):
    return [d.line for d in diags if d.rule == rule]


# ---------------------------------------------------------------------------
# R001 compat-only-imports


def test_r001_flags_shard_map_import():
    diags = check_source("import jax.experimental.shard_map as sm\n",
                         "src/repro/launch/fixture.py")
    assert rules(diags) == ["R001"]
    assert lines(diags, "R001") == [1]


def test_r001_flags_axis_type_from_import():
    diags = check_source("from jax.sharding import AxisType\n",
                         "src/repro/launch/fixture.py")
    assert rules(diags) == ["R001"]


def test_r001_flags_attribute_use():
    code = ("import jax\n"
            "\n"
            "def f(mesh):\n"
            "    with jax.set_mesh(mesh):\n"
            "        pass\n")
    diags = check_source(code, "src/repro/launch/fixture.py")
    assert rules(diags) == ["R001"]
    assert lines(diags, "R001") == [4]


def test_r001_clean_via_compat():
    code = ("from repro import compat\n"
            "\n"
            "def f(mesh):\n"
            "    with compat.set_mesh(mesh):\n"
            "        pass\n")
    assert check_source(code, "src/repro/launch/fixture.py") == []


def test_r001_whitelists_compat_itself():
    code = ("import jax\n"
            "\n"
            "HAS = hasattr(jax, 'set_mesh')\n"
            "from jax.sharding import AxisType\n")
    assert check_source(code, "src/repro/compat.py") == []


# ---------------------------------------------------------------------------
# R002 no-full-n


def test_r002_flags_materialize():
    code = ("def radius(source):\n"
            "    x = source.materialize()\n"
            "    return x\n")
    diags = check_source(code, CORE)
    assert rules(diags) == ["R002"]
    assert lines(diags, "R002") == [2]


def test_r002_flags_asarray_of_source():
    code = ("import numpy as np\n"
            "\n"
            "def f(source):\n"
            "    return np.asarray(source)\n")
    diags = check_source(code, CORE)
    assert rules(diags) == ["R002"]
    assert lines(diags, "R002") == [4]


def test_r002_flags_concat_over_blocks():
    code = ("import jax.numpy as jnp\n"
            "\n"
            "def f(src, rows):\n"
            "    return jnp.concatenate([b for b in src.blocks(rows)])\n")
    diags = check_source(code, CORE)
    assert rules(diags) == ["R002"]


def test_r002_flags_take_of_full_arange():
    code = ("import numpy as np\n"
            "\n"
            "def f(source):\n"
            "    return source.take(np.arange(source.n))\n")
    diags = check_source(code, CORE)
    assert rules(diags) == ["R002"]


def test_r002_oracle_materialize_is_exempt():
    code = ("class A:\n"
            "    def materialize(self):\n"
            "        return self._parent.materialize()\n")
    assert check_source(code, CORE) == []


def test_r002_clean_bounded_take_and_fold():
    code = ("import numpy as np\n"
            "\n"
            "def g(source, a, b):\n"
            "    return source.take(np.arange(a, b))\n"
            "\n"
            "def fold(source, rows):\n"
            "    acc = 0.0\n"
            "    for b in source.blocks(rows):\n"
            "        acc += float(b.sum())\n"
            "    return acc\n")
    assert check_source(code, CORE) == []


def test_r002_out_of_scope_outside_core_and_data():
    code = ("def f(source):\n"
            "    return source.materialize()\n")
    assert check_source(code, "src/repro/serve/fixture.py") == []


# ---------------------------------------------------------------------------
# R003 sampler-key-discipline


def test_r003_flags_direct_draw():
    code = ("import jax\n"
            "\n"
            "def f(key, n):\n"
            "    return jax.random.uniform(key, (n,))\n")
    diags = check_source(code, CORE)
    assert rules(diags) == ["R003"]
    assert lines(diags, "R003") == [4]


def test_r003_flags_draw_from_import():
    diags = check_source("from jax.random import uniform\n", CORE)
    assert rules(diags) == ["R003"]


def test_r003_allows_key_management_and_engine_samplers():
    code = ("import jax\n"
            "from repro.kernels import engine\n"
            "\n"
            "def f(key, a, b):\n"
            "    k1, k2 = jax.random.split(key, 2)\n"
            "    jax.random.key_data(k1)\n"
            "    return engine.uniform_rows(k2, a, b)\n")
    assert check_source(code, CORE) == []


def test_r003_out_of_scope_in_serve():
    code = ("import jax\n"
            "\n"
            "def f(key, n):\n"
            "    return jax.random.uniform(key, (n,))\n")
    assert check_source(code, "src/repro/serve/fixture.py") == []


# ---------------------------------------------------------------------------
# R004 recompile-hazard


def test_r004_flags_ragged_block_into_jitted_call():
    code = ("import jax\n"
            "\n"
            "@jax.jit\n"
            "def f(b):\n"
            "    return b.sum()\n"
            "\n"
            "def g(src, rows):\n"
            "    out = []\n"
            "    for blk in src.blocks(rows):\n"
            "        out.append(f(blk))\n"
            "    return out\n")
    diags = check_source(code, CORE)
    assert rules(diags) == ["R004"]
    assert lines(diags, "R004") == [10]


def test_r004_flags_shape_probe_into_jit_wrapped_call():
    code = ("import jax\n"
            "\n"
            "def fn(n):\n"
            "    return n\n"
            "\n"
            "h = jax.jit(fn)\n"
            "\n"
            "def g(src, rows):\n"
            "    for blk in src.blocks(rows):\n"
            "        h(blk.shape[0])\n")
    diags = check_source(code, CORE)
    assert rules(diags) == ["R004"]
    assert lines(diags, "R004") == [10]


def test_r004_clean_after_pad_to_rows():
    code = ("import jax\n"
            "import jax.numpy as jnp\n"
            "\n"
            "@jax.jit\n"
            "def f(b):\n"
            "    return b.sum()\n"
            "\n"
            "def g(src, rows):\n"
            "    out = []\n"
            "    for blk in src.blocks(rows):\n"
            "        nb = blk.shape[0]\n"
            "        if nb < rows:\n"
            "            blk = jnp.pad(blk, ((0, rows - nb), (0, 0)))\n"
            "        out.append(f(blk))\n"
            "    return out\n")
    assert check_source(code, CORE) == []


def test_r004_fixed_shape_streams_not_flagged():
    code = ("import jax\n"
            "\n"
            "@jax.jit\n"
            "def f(b):\n"
            "    return b.sum()\n"
            "\n"
            "def g(steps):\n"
            "    for blk, mask in stream_device(steps):\n"
            "        f(blk)\n")
    assert check_source(code, CORE) == []


def test_r004_eager_callees_not_flagged():
    code = ("def g(src, rows, ops, centers):\n"
            "    for blk in src.blocks(rows):\n"
            "        ops.dist2_to_center(blk, centers)\n")
    assert check_source(code, CORE) == []


# ---------------------------------------------------------------------------
# R005 x64-hygiene


def test_r005_flags_wide_dtype_and_shift():
    code = ("import jax.numpy as jnp\n"
            "\n"
            "def _philox_rows(c, k):\n"
            "    return (c.astype(jnp.int64) << 32) | k\n")
    diags = check_source(code, ENGINE)
    assert set(rules(diags)) == {"R005"}
    assert 4 in lines(diags, "R005")


def test_r005_clean_uint32_limbs():
    code = ("import jax.numpy as jnp\n"
            "\n"
            "def _philox_rows(c, k):\n"
            "    hi = (c >> jnp.uint32(16)).astype(jnp.uint32)\n"
            "    return hi ^ k\n")
    assert check_source(code, ENGINE) == []


def test_r005_scoped_to_engine_philox_helpers():
    code = ("import jax.numpy as jnp\n"
            "\n"
            "def _philox_rows(c, k):\n"
            "    return (c.astype(jnp.int64) << 32) | k\n")
    assert check_source(code, CORE) == []
    host = ("import numpy as np\n"
            "\n"
            "def split_index_words(start):\n"
            "    return np.uint64(start) >> np.uint64(32)\n")
    assert check_source(host, ENGINE) == []


# ---------------------------------------------------------------------------
# suppressions


def test_suppression_inline_round_trip():
    code = ("def f(source):\n"
            "    return source.materialize()"
            "  # reprolint: disable=R002 -- device-resident branch, "
            "documented contract\n")
    assert check_source(code, CORE) == []


def test_suppression_standalone_line_above():
    code = ("def f(source):\n"
            "    # reprolint: disable=R002 -- device-resident branch, "
            "documented contract\n"
            "    return source.materialize()\n")
    assert check_source(code, CORE) == []


def test_suppression_without_justification_is_an_error():
    code = ("def f(source):\n"
            "    return source.materialize()  # reprolint: disable=R002\n")
    diags = check_source(code, CORE)
    assert sorted(rules(diags)) == ["R000", "R002"]


def test_suppression_unknown_rule_id_is_an_error():
    code = ("x = 1  # reprolint: disable=R999 -- justified but bogus id\n")
    diags = check_source(code, CORE)
    assert rules(diags) == ["R000"]
    assert "R999" in diags[0].message


def test_suppression_does_not_silence_other_rules():
    code = ("def f(source):\n"
            "    return source.materialize()"
            "  # reprolint: disable=R003 -- wrong rule id on purpose\n")
    diags = check_source(code, CORE)
    assert rules(diags) == ["R002"]


def test_syntax_error_is_reported_not_raised():
    diags = check_source("def f(:\n", CORE)
    assert rules(diags) == ["E999"]


# ---------------------------------------------------------------------------
# CLI


def test_cli_clean_tree_exits_zero():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.reprolint",
         "src", "benchmarks", "examples"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_reports_file_line_rule_and_exits_nonzero(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("from jax.sharding import AxisType\n", encoding="utf-8")
    proc = subprocess.run(
        [sys.executable, "-m", "tools.reprolint", str(bad),
         "--output", str(tmp_path / "diag.txt")],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 1
    line = proc.stdout.strip().splitlines()[0]
    assert "bad.py:1 R001" in line
    assert (tmp_path / "diag.txt").read_text(encoding="utf-8").strip() == line
