"""Worker-side scenarios for the multi-process cluster harness.

Each function here runs *inside every worker process* of a cluster
spawned by ``repro.launch.cluster`` (target string
``tests/distributed/scenarios.py:<name>``), after
``jax.distributed.initialize`` has succeeded.  A scenario receives the
``WorkerContext`` and returns a JSON-serializable dict; the harness
collects one verdict per process over the stdout pipe and the pytest
parent compares them — against each other (SPMD replication) and against
single-process references it computes itself.

Bitwise transport: centers/radii are float32; float32 -> python float
(double) -> JSON -> float32 round-trips exactly, so verdict equality is
bit equality.

The global dataset is always ``synthetic_source("unif", n, seed=SEED)``
sharded by the same ceil-split as ``shard_source`` — process ``p`` holds
a ``SliceSource`` view of its own row range (regenerated locally, never
exchanged), so the parent can rebuild the identical logical input
without any worker materializing anything remote.
"""
from __future__ import annotations

import numpy as np

import jax

from repro import compat
from repro.core.eim import eim, eim_sample
from repro.core.executor import MeshExecutor
from repro.core.mrg import mrg
from repro.data import ProcessShardedSource, SliceSource, synthetic_source
from repro.data.source import DEFAULT_PREFETCH, stream_device
from repro.launch.mesh import make_cluster_mesh, make_mesh

SEED = 7


def split_offsets(n: int, parts: int) -> list:
    """``shard_source``'s ceil-split: part ``i`` is rows
    ``[i*per, min((i+1)*per, n))`` with ``per = ceil(n/parts)`` — the
    final shard is ragged whenever ``parts`` does not divide ``n``."""
    per = -(-n // parts)
    return [min(i * per, n) for i in range(parts + 1)]


class SpySource:
    """Wraps this process's local shard and records every read.

    Proves the residency contract per process: the shard is streamed in
    <= block_rows pieces, ``materialize`` is never called, and random
    access (the O(k) candidate exchange) touches far fewer rows than the
    shard holds.
    """

    def __init__(self, inner):
        self._inner = inner
        self.max_block_rows = 0
        self.blocks_read = 0
        self.take_rows = 0
        self.max_take_rows = 0
        self.materialize_calls = 0

    @property
    def n(self):
        return self._inner.n

    @property
    def d(self):
        return self._inner.d

    def host_blocks(self, block_rows):
        for b in self._inner.host_blocks(block_rows):
            self.blocks_read += 1
            self.max_block_rows = max(self.max_block_rows, int(b.shape[0]))
            yield b

    def blocks(self, block_rows, *, prefetch=DEFAULT_PREFETCH):
        return stream_device(self.host_blocks(block_rows), prefetch)

    def take(self, indices):
        idx = np.asarray(indices).reshape(-1)
        self.take_rows += int(idx.size)
        self.max_take_rows = max(self.max_take_rows, int(idx.size))
        return self._inner.take(idx)

    def row(self, idx):
        self.take_rows += 1
        self.max_take_rows = max(self.max_take_rows, 1)
        return self._inner.row(idx)

    def materialize(self):
        self.materialize_calls += 1
        raise RuntimeError(
            "spy: materialize() called on a local shard — multi-process "
            "streaming must never hold a whole shard at once")

    def spy_report(self) -> dict:
        return {
            "local_n": int(self.n),
            "max_block_rows": int(self.max_block_rows),
            "blocks_read": int(self.blocks_read),
            "take_rows": int(self.take_rows),
            "max_take_rows": int(self.max_take_rows),
            "materialize_calls": int(self.materialize_calls),
        }


def build_sharded(ctx, n: int, d: int):
    """This process's view of the global partition: a spy-wrapped
    ``SliceSource`` of the common synthetic parent for the local range,
    ``RemoteShard`` stubs everywhere else."""
    offs = split_offsets(n, ctx.num_processes)
    sizes = [offs[i + 1] - offs[i] for i in range(ctx.num_processes)]
    base = synthetic_source("unif", n, seed=SEED, d=d)
    pid = ctx.process_id
    spy = SpySource(SliceSource(base, offs[pid], offs[pid + 1]))
    src = ProcessShardedSource.for_process(spy, sizes, pid)
    return src, spy


def _f32_list(a) -> list:
    return np.asarray(a, np.float32).tolist()


def _mask_idx(mask) -> list:
    return [int(i) for i in np.nonzero(np.asarray(mask))[0]]


# -- main scenarios ---------------------------------------------------------


def parity(ctx) -> dict:
    """MRG round 1+2 and full streamed EIM over the global mesh, each
    process feeding only its own shard.  Returns every result bit the
    parent needs for the single-process parity check."""
    a = ctx.args
    n, d = int(a["n"]), int(a["d"])
    k, eim_k = int(a["k"]), int(a["eim_k"])
    block_rows = int(a["block_rows"])
    eps, phi = float(a["eps"]), float(a["phi"])

    src, spy = build_sharded(ctx, n, d)
    mesh = make_cluster_mesh()
    ex = MeshExecutor(mesh, block_rows=block_rows)

    m = mrg(src, k, executor=ex)
    e = eim(src, eim_k, jax.random.PRNGKey(int(a["key"])),
            eps=eps, phi=phi, executor=ex)

    return {
        "mrg_centers": _f32_list(m.centers),
        "mrg_radius2": float(np.float32(m.radius2)),
        "mrg_rounds": int(m.rounds),
        "eim_centers": _f32_list(e.centers),
        "eim_radius2": float(np.float32(e.radius2)),
        "eim_iters": int(e.sample.iters),
        "eim_sampled": int(e.sample.sampled),
        "sample_idx": _mask_idx(e.sample.sample_mask),
        "s_idx": _mask_idx(e.sample.s_mask),
        "spy": spy.spy_report(),
    }


def eim_draws(ctx) -> dict:
    """EIM Round-1 sampling only — the determinism-grid scenario.  The
    Philox draws are keyed on absolute global row ids, so the returned
    index sets must be bitwise identical for any process count."""
    a = ctx.args
    src, spy = build_sharded(ctx, int(a["n"]), int(a["d"]))
    mesh = make_cluster_mesh()
    ex = MeshExecutor(mesh, block_rows=int(a["block_rows"]))
    s = eim_sample(src, int(a["k"]), jax.random.PRNGKey(int(a["key"])),
                   eps=float(a["eps"]), phi=float(a["phi"]), executor=ex)
    return {
        "sample_idx": _mask_idx(s.sample_mask),
        "s_idx": _mask_idx(s.s_mask),
        "iters": int(s.iters),
        "overflow": bool(s.overflow),
        "sampled": int(s.sampled),
        "x64": bool(jax.config.jax_enable_x64),
        "spy": spy.spy_report(),
    }


def assembly(ctx) -> dict:
    """``compat.global_array_from_shards`` in the genuine multi-process
    regime: local pieces only (``None`` for remote shards), plus the
    fetch/replicate/exchange primitives the executors are built on."""
    from jax.sharding import PartitionSpec as P

    rows, d = 6, 3
    mesh = make_cluster_mesh()
    num_shards = mesh.devices.size
    pspec = P(mesh.axis_names[0])

    local_ids = compat.local_shard_indices(mesh, pspec, num_shards)

    def piece(s: int) -> np.ndarray:
        return (np.arange(rows * d, dtype=np.float32).reshape(rows, d)
                + 1000.0 * s)

    pieces = [piece(s) if s in local_ids else None
              for s in range(num_shards)]
    arr = compat.global_array_from_shards(mesh, pspec, pieces)

    full = compat.fetch_global(arr)
    expect = np.concatenate([piece(s) for s in range(num_shards)])
    assert arr.shape == (num_shards * rows, d)
    assert np.array_equal(full, expect), "allgathered bits differ"

    for sh in arr.addressable_shards:
        s = (sh.index[0].start or 0) // rows
        assert s in local_ids
        assert np.array_equal(np.asarray(sh.data), piece(s))

    none_local_raised = False
    if compat.process_count() > 1:
        bad = list(pieces)
        bad[local_ids[0]] = None
        try:
            compat.global_array_from_shards(mesh, pspec, bad)
        except ValueError:
            none_local_raised = True

    rep = compat.replicated_array(mesh, expect[:4])
    assert np.array_equal(compat.fetch_global(rep), expect[:4])

    ex = compat.exchange_host(np.float32([compat.process_index()]))
    assert ex.shape == (compat.process_count(), 1)
    assert [int(v) for v in ex[:, 0]] == list(range(compat.process_count()))

    return {
        "full_sum": float(np.float64(expect.sum())),
        "fetched_sum": float(np.float64(np.asarray(full, np.float64).sum())),
        "local_ids": [int(i) for i in local_ids],
        "none_local_raised": bool(none_local_raised),
    }


def cluster_env(ctx) -> dict:
    """Mesh/topology facts the parent asserts: process-major global device
    order, global-vs-local device counts, local shard ownership."""
    from jax.sharding import PartitionSpec as P

    mesh = make_cluster_mesh()
    devs = list(mesh.devices.flat)
    same = make_mesh((len(jax.devices()),), (mesh.axis_names[0],))
    return {
        "process_index": int(compat.process_index()),
        "process_count": int(compat.process_count()),
        "global_devices": len(jax.devices()),
        "local_devices": len(jax.local_devices()),
        "mesh_owners": [int(d.process_index) for d in devs],
        "make_mesh_matches": list(same.devices.flat) == devs,
        "local_shard_ids": [int(i) for i in compat.local_shard_indices(
            mesh, P(mesh.axis_names[0]), len(devs))],
    }


# -- fault-path scenarios ---------------------------------------------------


def trivial(ctx) -> dict:
    return {"pid": int(ctx.process_id)}


def crash_mid_round(ctx) -> dict:
    """One process dies after a successful collective; survivors block in
    the next collective until the harness reaps them."""
    x = compat.exchange_host(np.float32([ctx.process_id]))
    if ctx.process_id == int(ctx.args.get("crash_on", 1)):
        raise RuntimeError("boom mid-round (scenario-injected fault)")
    compat.exchange_host(np.float32([float(x.sum())]))
    return {"pid": int(ctx.process_id)}
