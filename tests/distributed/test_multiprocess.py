"""Real multi-process ``jax.distributed`` runs, checked bitwise against
single-process references.

Every test here spawns an actual cluster of worker processes (own
Python interpreters, ``jax.distributed.initialize`` against a localhost
coordinator, gloo CPU collectives) through the harness, then compares
the per-process verdicts against references computed *in this pytest
process* with the single-process executors over the identical logical
input.  The contract is bit equality, not tolerance: multi-process
``mrg`` and streamed ``eim`` must produce the same float32 bits as
``SimExecutor`` / ``HostStreamExecutor`` for matching blockings.
"""
from __future__ import annotations

import numpy as np
import pytest

jax = pytest.importorskip("jax")

import harness  # noqa: E402
import scenarios  # noqa: E402

from repro import compat  # noqa: E402
from repro.core.eim import eim, eim_sample  # noqa: E402
from repro.core.executor import HostStreamExecutor, SimExecutor  # noqa: E402
from repro.core.mrg import mrg  # noqa: E402
from repro.data import shard_source, synthetic_source  # noqa: E402

pytestmark = pytest.mark.skipif(
    not compat.HAS_DISTRIBUTED,
    reason="this jax build has no jax.distributed runtime")

# One parameter set per parity cell. eps/k are chosen so the EIM
# sampling loop engages without covering everything (pop ≈ 0.6·n after
# ~6 iterations at these values) — both the degenerate all-sampled path
# and the never-engaged path would skip the cross-process machinery.
PARITY = dict(n=6144, d=3, k=4, eim_k=2, eps=0.1, phi=8.0, key=0)
GRID = dict(n=6001, d=3, k=2, eps=0.1, phi=8.0, key=3, block_rows=512)


def _ref_source(n: int, d: int, shards: int):
    base = synthetic_source("unif", n, seed=scenarios.SEED, d=d)
    return shard_source(base, shards)


def _assert_spy(verdict: dict, block_rows: int) -> None:
    """No process materialized more than its own shard: streaming stayed
    within block_rows, materialize() never ran, and random access (the
    O(k) candidate exchange) touched far fewer rows than the shard."""
    spy = verdict["spy"]
    assert spy["materialize_calls"] == 0
    assert spy["blocks_read"] > 0
    assert 0 < spy["max_block_rows"] <= block_rows
    # random access is per-call bounded by the candidate-set size, never
    # a whole-shard gather (cumulative rows across iterations may exceed
    # the shard; resident-at-once rows must not)
    assert spy["max_take_rows"] < spy["local_n"]


_PER_PROCESS_KEYS = ("spy", "process_id", "ok")


def _assert_replicated(verdicts: list) -> None:
    """SPMD: every process must report identical bits."""
    def shared(v):
        return {k: w for k, w in v.items() if k not in _PER_PROCESS_KEYS}
    for v in verdicts[1:]:
        assert shared(v) == shared(verdicts[0])


def test_two_process_parity_vs_host_stream():
    """2-process mrg + streamed eim == HostStreamExecutor over the same
    ShardedSource with the same block_rows, bit for bit."""
    p = dict(PARITY, block_rows=512)
    verdicts = harness.run("parity", 2, args=p, tag="parity-hs")
    _assert_replicated(verdicts)
    for v in verdicts:
        _assert_spy(v, p["block_rows"])

    src = _ref_source(p["n"], p["d"], 2)
    hs = HostStreamExecutor(block_rows=p["block_rows"])
    m = mrg(src, p["k"], executor=hs)
    e = eim(src, p["eim_k"], jax.random.PRNGKey(p["key"]),
            eps=p["eps"], phi=p["phi"], executor=hs)

    v = verdicts[0]
    np.testing.assert_array_equal(
        np.asarray(v["mrg_centers"], np.float32),
        np.asarray(m.centers, np.float32))
    assert np.float32(v["mrg_radius2"]) == np.float32(m.radius2)
    assert v["mrg_rounds"] == m.rounds
    np.testing.assert_array_equal(
        np.asarray(v["eim_centers"], np.float32),
        np.asarray(e.centers, np.float32))
    assert np.float32(v["eim_radius2"]) == np.float32(e.radius2)
    assert v["eim_iters"] == e.sample.iters
    assert v["sample_idx"] == np.nonzero(np.asarray(e.sample.sample_mask))[0].tolist()
    assert v["s_idx"] == np.nonzero(np.asarray(e.sample.s_mask))[0].tolist()


def test_two_process_parity_vs_sim_executor():
    """With one block per equal shard the mesh blocking *is* SimExecutor's
    machine blocking — the 2-process run must reproduce the simulated
    2-machine reference exactly (mrg and eim)."""
    per = PARITY["n"] // 2
    assert per * 2 == PARITY["n"]
    p = dict(PARITY, block_rows=per)
    verdicts = harness.run("parity", 2, args=p, tag="parity-sim")
    _assert_replicated(verdicts)
    for v in verdicts:
        _assert_spy(v, per)

    x = np.asarray(
        synthetic_source("unif", p["n"], seed=scenarios.SEED,
                         d=p["d"]).materialize())
    sim = SimExecutor(m=2)
    m = mrg(x, p["k"], executor=sim)
    e = eim(x, p["eim_k"], jax.random.PRNGKey(p["key"]),
            eps=p["eps"], phi=p["phi"], executor=sim)

    v = verdicts[0]
    np.testing.assert_array_equal(
        np.asarray(v["mrg_centers"], np.float32),
        np.asarray(m.centers, np.float32))
    assert np.float32(v["mrg_radius2"]) == np.float32(m.radius2)
    np.testing.assert_array_equal(
        np.asarray(v["eim_centers"], np.float32),
        np.asarray(e.centers, np.float32))
    assert np.float32(v["eim_radius2"]) == np.float32(e.radius2)
    assert v["sample_idx"] == np.nonzero(np.asarray(e.sample.sample_mask))[0].tolist()


def test_eim_draws_deterministic_across_process_counts():
    """The determinism grid: EIM Round-1 draws are keyed on absolute
    global row ids, so the sampled index sets are bitwise identical for
    1, 2 and 4 processes — n is chosen so the final shard is ragged for
    both multi-process cells, and the 2-process cell additionally pins
    x64 off explicitly."""
    ref_src = _ref_source(GRID["n"], GRID["d"], 2)
    ref = eim_sample(ref_src, GRID["k"], jax.random.PRNGKey(GRID["key"]),
                     eps=GRID["eps"], phi=GRID["phi"],
                     executor=HostStreamExecutor(
                         block_rows=GRID["block_rows"]))
    ref_sample = np.nonzero(np.asarray(ref.sample_mask))[0].tolist()
    ref_s = np.nonzero(np.asarray(ref.s_mask))[0].tolist()
    assert 0 < len(ref_sample) < GRID["n"], "sampling loop must engage"

    cells = [(1, None), (2, {"JAX_ENABLE_X64": "0"}), (4, None)]
    for procs, env in cells:
        verdicts = harness.run("eim_draws", procs, args=GRID, env=env,
                               tag=f"draws-p{procs}")
        _assert_replicated(verdicts)
        for v in verdicts:
            assert v["sample_idx"] == ref_sample, f"P={procs}"
            assert v["s_idx"] == ref_s, f"P={procs}"
            assert v["iters"] == ref.iters
            assert v["overflow"] == bool(ref.overflow)
            assert v["sampled"] == int(ref.sampled)
            assert v["x64"] is False
            _assert_spy(v, GRID["block_rows"])


def test_global_array_assembly_multiprocess():
    """compat.global_array_from_shards across real process boundaries:
    local pieces only, None for remote shards, allgather returns the full
    bits, and a None local piece raises."""
    for procs in (1, 2):
        verdicts = harness.run("assembly", procs, tag=f"assembly-p{procs}")
        for pid, v in enumerate(verdicts):
            assert v["fetched_sum"] == v["full_sum"]
            assert v["local_ids"] == [pid] if procs > 1 else [0]
            assert v["none_local_raised"] == (procs > 1)


def test_cluster_mesh_topology():
    """make_cluster_mesh spans the *global* device set process-major and
    local_shard_indices maps each process to exactly its own shard."""
    verdicts = harness.run("cluster_env", 2, tag="cluster-env")
    for pid, v in enumerate(verdicts):
        assert v["process_index"] == pid
        assert v["process_count"] == 2
        assert v["global_devices"] == 2
        assert v["local_devices"] == 1
        assert v["mesh_owners"] == [0, 1]
        assert v["make_mesh_matches"] is True
        assert v["local_shard_ids"] == [pid]
