"""Fault paths of the cluster harness: every failure mode must surface
the child's traceback in the pytest error *within the timeout* — a dead
or wedged worker must never hang CI for the full hard deadline."""
from __future__ import annotations

import socket
import time

import pytest

jax = pytest.importorskip("jax")

import harness  # noqa: E402

from repro import compat  # noqa: E402

pytestmark = pytest.mark.skipif(
    not compat.HAS_DISTRIBUTED,
    reason="this jax build has no jax.distributed runtime")


def test_worker_dies_pre_initialize():
    """An import-time failure in the scenario module kills every worker
    before it reaches the coordination barrier; the parent reports the
    traceback immediately instead of waiting out the timeout."""
    t0 = time.monotonic()
    with pytest.raises(harness.ClusterError) as ei:
        harness.run_scenario(harness.FAULTY_IMPORT + ":never", 2,
                             timeout=120, log_dir=None)
    elapsed = time.monotonic() - t0
    msg = str(ei.value)
    assert "boom at import" in msg
    assert "RuntimeError" in msg
    assert elapsed < 60, f"pre-init fault took {elapsed:.0f}s to surface"
    assert all(not r.ok for r in ei.value.results)
    assert not any(r.timed_out for r in ei.value.results)


def test_worker_raises_mid_round():
    """One worker raises between collectives; the survivor is blocked in
    a dead collective and must be reaped by the early-exit rule, with the
    crashed worker's traceback in the report."""
    t0 = time.monotonic()
    with pytest.raises(harness.ClusterError) as ei:
        harness.run("crash_mid_round", 2, args={"crash_on": 1},
                    timeout=180, tag="fault-mid-round")
    elapsed = time.monotonic() - t0
    msg = str(ei.value)
    assert "boom mid-round" in msg
    assert elapsed < 120, f"mid-round fault took {elapsed:.0f}s to surface"
    crashed = ei.value.results[1]
    assert crashed.returncode not in (0, None)
    assert not crashed.timed_out
    # the survivor either got reaped (killed) or failed its collective —
    # both are acceptable; hanging to the hard deadline is not.
    assert not any(r.timed_out for r in ei.value.results)


def test_coordinator_port_collision():
    """A coordinator that cannot bind its port must fail the run quickly
    (bounded by init_timeout + grace), with the child error surfaced."""
    blocker = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    blocker.bind(("127.0.0.1", 0))
    blocker.listen(1)
    port = blocker.getsockname()[1]
    try:
        t0 = time.monotonic()
        with pytest.raises(harness.ClusterError) as ei:
            harness.run("trivial", 2, coordinator_port=port,
                        init_timeout=10, timeout=120,
                        tag="fault-port-collision")
        elapsed = time.monotonic() - t0
        assert elapsed < 100, f"port collision took {elapsed:.0f}s"
        assert any(not r.ok for r in ei.value.results)
        # at least one child's own error text made it into the report
        msg = str(ei.value)
        assert "worker" in msg and ("Error" in msg or "error" in msg)
    finally:
        blocker.close()
