"""pytest-facing wrapper around ``repro.launch.cluster``.

Resolves scenario names to ``tests/distributed/scenarios.py:<fn>``
targets, threads ``CLUSTER_LOG_DIR`` (set by CI) through as per-worker
log capture, and re-exports the pieces the tests assert on.  All the
process management — spawn, pipe drain, verdict parse, early-exit
reaping, hard-kill on timeout — lives in ``repro.launch.cluster``; this
module only names scenarios.
"""
from __future__ import annotations

import os

from repro.launch.cluster import (  # noqa: F401  (re-exported for tests)
    ClusterError,
    WorkerResult,
    free_port,
    launch_cluster,
    run_scenario,
)

_HERE = os.path.dirname(os.path.abspath(__file__))
SCENARIOS = os.path.join(_HERE, "scenarios.py")
FAULTY_IMPORT = os.path.join(_HERE, "_faulty_import.py")

# CI jobs give each cluster plenty of headroom but the workflow has a
# hard job timeout; locally these all finish in seconds to ~a minute.
DEFAULT_TIMEOUT = 300.0


def scenario_target(name: str) -> str:
    return f"{SCENARIOS}:{name}"


def _log_dir(tag: str):
    base = os.environ.get("CLUSTER_LOG_DIR")
    if not base:
        return None
    path = os.path.join(base, tag)
    os.makedirs(path, exist_ok=True)
    return path


def run(name: str, num_processes: int, *, args=None,
        timeout: float = DEFAULT_TIMEOUT, tag: str | None = None,
        **kwargs) -> list:
    """Run scenario ``name`` in an ``num_processes``-worker cluster and
    return the per-process verdict dicts (process order).  Raises
    ``ClusterError`` — with every worker's traceback/output tail — on any
    failure, timeout included."""
    kwargs.setdefault("log_dir", _log_dir(tag or f"{name}-p{num_processes}"))
    return run_scenario(scenario_target(name), num_processes,
                        args=args, timeout=timeout, **kwargs)
