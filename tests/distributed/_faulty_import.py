"""A scenario module that dies at import time — the pre-``initialize``
fault case for the harness tests.  The worker loads its target *before*
calling ``jax.distributed.initialize``, so this failure must surface as
a traceback in the parent without any process ever joining the
coordination barrier (where it could hang the whole cluster)."""

raise RuntimeError("boom at import (pre-initialize scenario fault)")


def never(ctx):  # pragma: no cover - unreachable past the raise above
    return {}
