"""Tests for the serving engine (continuous batching) and streaming
k-center."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Only the last test is property-based; the serving/streaming tests must
# keep running when hypothesis is absent, so the import is guarded per-test
# rather than skipping the whole module.
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
    SET = settings(max_examples=10, deadline=None,
                   suppress_health_check=[HealthCheck.too_slow])
except ImportError:
    HAS_HYPOTHESIS = False

from repro.configs import get_config
from repro.models import init_params


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

def test_greedy_sampling_is_argmax():
    from repro.serve import sample
    logits = jnp.asarray([[1.0, 5.0, 2.0], [3.0, 0.0, -1.0]])
    out = sample(logits, jax.random.PRNGKey(0), temperature=0.0)
    assert out.tolist() == [1, 0]


def test_top_k_restricts_support():
    from repro.serve import sample
    logits = jnp.asarray([[0.0, 10.0, 9.0, -5.0]])
    for seed in range(20):
        t = int(sample(logits, jax.random.PRNGKey(seed), temperature=1.0,
                       top_k=2)[0])
        assert t in (1, 2)


def test_top_p_keeps_head():
    from repro.serve import sample
    logits = jnp.asarray([[10.0, 0.0, 0.0, 0.0]])
    for seed in range(10):
        t = int(sample(logits, jax.random.PRNGKey(seed), temperature=1.0,
                       top_p=0.5)[0])
        assert t == 0


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_config("qwen2_0_5b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_continuous_batching(engine_setup):
    from repro.serve import Engine, Request
    cfg, params = engine_setup
    eng = Engine(params, cfg, slots=3, s_max=48)
    for i in range(5):  # more requests than slots
        eng.submit(Request(uid=i, tokens=np.arange(4 + i), max_new=6))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)
    assert all(r.t_done >= r.t_first >= r.t_submit for r in done)


def test_engine_matches_plain_decode(engine_setup):
    """Greedy engine output == straight prefill+decode for one request."""
    from repro.models import decode_step, prefill
    from repro.serve import Engine, Request
    cfg, params = engine_setup
    prompt = np.arange(8) % cfg.vocab_size

    eng = Engine(params, cfg, slots=2, s_max=32)
    eng.submit(Request(uid=0, tokens=prompt, max_new=5))
    done = eng.run()
    got = done[0].out

    logits, cache = prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                            cfg, 32)
    want = [int(jnp.argmax(logits[0, -1]))]
    tok = jnp.asarray([[want[-1]]], jnp.int32)
    for _ in range(4):
        logits, cache = decode_step(params, cache, tok, cfg)
        want.append(int(jnp.argmax(logits[0, -1])))
        tok = jnp.asarray([[want[-1]]], jnp.int32)
    assert got == want


def test_engine_eos_frees_slot(engine_setup):
    from repro.serve import Engine, Request
    cfg, params = engine_setup
    eng = Engine(params, cfg, slots=1, s_max=32)
    eng.submit(Request(uid=0, tokens=np.arange(4), max_new=100, eos_id=-2))
    eng.submit(Request(uid=1, tokens=np.arange(4), max_new=3))
    done = eng.run(max_steps=200)
    # request 0 runs until cache limit, request 1 still completes after
    assert {r.uid for r in done} == {0, 1}


# ---------------------------------------------------------------------------
# streaming k-center
# ---------------------------------------------------------------------------

def test_streaming_guarantee_vs_gon():
    from repro.core import gonzalez, stream_init, stream_result, stream_update
    from repro.kernels import ops
    rng = np.random.default_rng(1)
    pts = rng.normal(size=(5000, 4)).astype(np.float32)
    st = stream_init(8, 4)
    for i in range(0, 5000, 500):
        st = stream_update(st, pts[i : i + 500])
    centers, r = stream_result(st)
    assert 1 <= centers.shape[0] <= 8
    _, d2 = ops.assign_nearest(jnp.asarray(pts), jnp.asarray(centers))
    rad = float(np.sqrt(np.max(np.asarray(d2))))
    g = float(jnp.sqrt(gonzalez(jnp.asarray(pts), 8).radius2))
    assert rad <= 8.0 * g + 1e-5  # 8-approx vs (>=OPT) baseline


if HAS_HYPOTHESIS:
    @given(n=st.integers(20, 200), k=st.integers(2, 6),
           seed=st.integers(0, 5))
    @SET
    def test_streaming_center_separation_invariant(n, k, seed):
        from repro.core import (stream_init, stream_result, stream_update)
        rng = np.random.default_rng(seed)
        pts = rng.normal(size=(n, 3)).astype(np.float32)
        st = stream_init(k, 3)
        st = stream_update(st, pts)
        centers, r = stream_result(st)
        assert centers.shape[0] <= k or r == 0.0
        if centers.shape[0] > 1 and r > 0:
            d2 = ((centers[:, None] - centers[None]) ** 2).sum(-1)
            np.fill_diagonal(d2, np.inf)
            # doubling invariant: pairwise separation > 4r
            assert np.sqrt(d2.min()) > 4.0 * r - 1e-4
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_streaming_center_separation_invariant():
        pass
