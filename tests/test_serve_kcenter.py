"""Tests for the online k-center serving engine (serve/kcenter.py) and the
host-side insertion tail of ``stream_update`` (core/streaming.py).

The three serving contracts pinned here:
  * every served ``assign`` is **bitwise** ``ops.assign_nearest`` on the
    snapshot centers of its answering epoch — including under interleaved
    ingest that bumps epochs mid-query-stream;
  * dispatch operand signatures are a function of the (query-bucket,
    center-bucket) pair only: after warmup, ragged query sizes and epoch
    bumps add ZERO new signatures (spy-asserted);
  * covered-point ingest (the steady state) bumps no epoch and refreshes
    no cache.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stream_init, stream_result, stream_update
from repro.data import gau
from repro.data.source import HostSource
from repro.kernels import ops
from repro.serve import AssignTicket, KCenterService


def _clustered(n, k, d, seed=0):
    return gau(n, k, d=d, seed=seed)


def _offline(q, centers):
    i, d2 = ops.assign_nearest(jnp.asarray(q), jnp.asarray(centers))
    return np.asarray(i), np.asarray(d2)


# ---------------------------------------------------------------------------
# served parity: bitwise vs the offline op, per epoch
# ---------------------------------------------------------------------------

def test_served_assign_bitwise_parity_every_epoch():
    k, d = 8, 6
    rng = np.random.default_rng(0)
    with KCenterService(k, d, snapshot_history=True) as svc:
        # three ingests at growing scale: each forces doublings, so we see
        # several distinct epochs
        for scale, seed in ((1.0, 1), (10.0, 2), (100.0, 3)):
            svc.submit_points(_clustered(600, k, d, seed=seed) * scale)
            svc.drain(timeout=120)
            q = rng.normal(size=(33, d)).astype(np.float32) * scale
            res = svc.assign(q, timeout=60)
            centers = svc.snapshot_at(res.epoch)
            ri, rd = _offline(q, centers)
            assert np.array_equal(ri, res.idx)
            assert np.array_equal(rd, res.d2)
        assert svc.stats["epochs"] >= 2


def test_single_center_sketch_parity():
    # an isotropic blob collapses the doubling sketch to ONE center — the
    # m=1 distance dot lowers as a matvec, which assign_bucketed must
    # special-case to stay bitwise with the unbucketed reference
    k, d = 16, 16
    rng = np.random.default_rng(3)
    # k+1 unit-sphere points in high d: max pairwise distance < 2 × min,
    # so the bootstrap merge at 4r = 2·min keeps exactly one center
    pts = rng.normal(size=(k + 1, d)).astype(np.float32)
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    with KCenterService(k, d) as svc:
        svc.submit_points(pts)
        svc.drain(timeout=120)
        epoch, centers, _ = svc.snapshot()
        assert centers.shape[0] == 1       # the degenerate regime under test
        q = rng.normal(size=(37, d)).astype(np.float32)
        res = svc.assign(q, timeout=60)
        ri, rd = _offline(q, centers)
        assert res.epoch == epoch
        assert np.array_equal(ri, res.idx)
        assert np.array_equal(rd, res.d2)


def test_parity_under_interleaved_ingest():
    """Epoch bumps racing a query stream: every answer must still be
    bitwise-correct for the centers of the epoch that answered it."""
    k, d = 8, 4
    rng = np.random.default_rng(1)
    with KCenterService(k, d, snapshot_history=True) as svc:
        svc.submit_points(_clustered(400, k, d, seed=0))
        svc.drain(timeout=120)

        stop = threading.Event()

        def feeder():
            scale = 1.0
            while not stop.is_set():
                # keep forcing center-set changes; wrap before f32 overflow
                scale = scale * 1.5 if scale < 1e12 else 1.0
                svc.submit_points(
                    _clustered(100, k, d, seed=7) * np.float32(scale))

        feed = threading.Thread(target=feeder, daemon=True)
        feed.start()
        try:
            for _ in range(40):
                q = rng.normal(size=(9, d)).astype(np.float32) * 10
                res = svc.assign(q, timeout=60)
                ri, rd = _offline(q, svc.snapshot_at(res.epoch))
                assert np.array_equal(ri, res.idx)
                assert np.array_equal(rd, res.d2)
        finally:
            stop.set()
            feed.join()
        svc.drain(timeout=120)
        assert svc.stats["epochs"] >= 2   # the race actually happened


# ---------------------------------------------------------------------------
# epoch discipline: steady state = zero invalidations
# ---------------------------------------------------------------------------

def test_covered_ingest_bumps_no_epoch_and_refreshes_no_cache():
    k, d = 8, 6
    with KCenterService(k, d) as svc:
        svc.submit_points(_clustered(800, k, d, seed=0))
        svc.drain(timeout=120)
        epoch0, centers, r = svc.snapshot()
        svc.assign(centers[:1], timeout=60)      # populate the cache
        st0 = svc.stats

        # points sitting exactly on (and 1e-6 off) the live centers are
        # covered: the sketch must absorb them without publishing
        for _ in range(5):
            svc.submit_points(centers)
            svc.submit_points(centers + 1e-6)
        svc.drain(timeout=120)
        assert svc.snapshot()[0] == epoch0

        svc.assign(centers[:3], timeout=60)
        st1 = svc.stats
        assert st1["epochs"] == st0["epochs"]
        assert st1["cache_refreshes"] == st0["cache_refreshes"]


# ---------------------------------------------------------------------------
# recompile discipline: one signature set, forever
# ---------------------------------------------------------------------------

def _spy_bucketed(monkeypatch, seen):
    real = ops.assign_bucketed

    def spy(q, c, cmask, **kw):
        seen.append((q.shape, c.shape, np.asarray(cmask).shape,
                     kw.get("impl"), kw.get("chunk")))
        return real(q, c, cmask, **kw)

    monkeypatch.setattr(ops, "assign_bucketed", spy)


def test_one_signature_across_ragged_batches_and_epochs(monkeypatch):
    k, d = 8, 6
    seen = []
    _spy_bucketed(monkeypatch, seen)
    with KCenterService(k, d, min_bucket=16, center_bucket_min=16,
                        snapshot_history=True) as svc:
        svc.submit_points(_clustered(600, k, d, seed=0))
        svc.drain(timeout=120)
        rng = np.random.default_rng(0)
        svc.assign(rng.normal(size=(5, d)).astype(np.float32), timeout=60)
        warm = set(seen)
        assert len(warm) == 1             # one (query-bucket, center-bucket)

        # ragged sizes all inside the same 16-row bucket
        for b in (1, 3, 7, 12, 16):
            svc.assign(rng.normal(size=(b, d)).astype(np.float32),
                       timeout=60)
        assert set(seen) == warm

        # an epoch bump within the same center bucket: cache re-uploads,
        # signatures must not move
        st_before = svc.stats
        svc.submit_points(_clustered(200, k, d, seed=1) * 50.0)
        svc.drain(timeout=120)
        assert svc.stats["epochs"] > st_before["epochs"]
        svc.assign(rng.normal(size=(9, d)).astype(np.float32), timeout=60)
        assert set(seen) == warm
        assert svc.stats["bucket_growths"] == 1   # only the initial fill


def test_query_buckets_are_pow2_and_capped(monkeypatch):
    k, d = 4, 3
    seen = []
    _spy_bucketed(monkeypatch, seen)
    with KCenterService(k, d, min_bucket=4, max_batch=8) as svc:
        svc.submit_points(_clustered(300, k, d, seed=0))
        svc.drain(timeout=120)
        rng = np.random.default_rng(0)
        # 21 rows > max_batch: slices of 8, 8, 5 → buckets 8, 8, 8
        svc.assign(rng.normal(size=(21, d)).astype(np.float32), timeout=60)
        qrows = [s[0][0] for s in seen]
        assert qrows == [8, 8, 8]
        seen.clear()
        svc.assign(rng.normal(size=(3, d)).astype(np.float32), timeout=60)
        assert [s[0][0] for s in seen] == [4]     # pow2 floor bucket


# ---------------------------------------------------------------------------
# batching behavior
# ---------------------------------------------------------------------------

def test_concurrent_clients_coalesce_and_stay_correct():
    k, d = 8, 5
    n_clients = 16
    with KCenterService(k, d, batch_wait_s=0.05) as svc:
        svc.submit_points(_clustered(500, k, d, seed=0))
        svc.drain(timeout=120)
        _, centers, _ = svc.snapshot()
        rng = np.random.default_rng(0)
        qs = [rng.normal(size=(1 + i % 4, d)).astype(np.float32)
              for i in range(n_clients)]
        tickets = [svc.assign_async(q) for q in qs]
        for q, t in zip(qs, tickets):
            res = t.result(timeout=60)
            ri, rd = _offline(q, centers)
            assert np.array_equal(ri, res.idx)
            assert np.array_equal(rd, res.d2)
        st = svc.stats
        assert st["queries"] == n_clients
        assert st["batches"] < n_clients          # coalescing happened
        assert st["batched_rows"] == sum(q.shape[0] for q in qs)


def test_unbatched_mode_dispatches_each_request_alone():
    k, d = 4, 3
    with KCenterService(k, d, batching=False) as svc:
        svc.submit_points(_clustered(200, k, d, seed=0))
        svc.drain(timeout=120)
        rng = np.random.default_rng(0)
        for _ in range(4):
            svc.assign(rng.normal(size=(2, d)).astype(np.float32),
                       timeout=60)
        st = svc.stats
        assert st["batches"] == st["queries"] == 4


def test_ticket_timestamps_and_done():
    k, d = 4, 3
    with KCenterService(k, d) as svc:
        svc.submit_points(_clustered(200, k, d, seed=0))
        svc.drain(timeout=120)
        t = svc.assign_async(np.zeros((1, d), np.float32))
        assert isinstance(t, AssignTicket)
        t.result(timeout=60)
        assert t.done()
        assert t.t_done >= t.t_submit


# ---------------------------------------------------------------------------
# ingest surface
# ---------------------------------------------------------------------------

def test_point_source_ingest_matches_offline_fold():
    k, d = 8, 4
    pts = _clustered(700, k, d, seed=2)
    with KCenterService(k, d, ingest_block_rows=128) as svc:
        svc.submit_points(HostSource(pts))
        svc.drain(timeout=120)
        _, centers, r = svc.snapshot()
    ref = stream_update(stream_init(k, d), HostSource(pts), block_rows=128)
    ref_c, ref_r = stream_result(ref)
    assert r == ref_r
    assert np.array_equal(centers, ref_c)


def test_ingest_error_surfaces_on_drain():
    with KCenterService(4, 3) as svc:
        with pytest.raises(ValueError):
            svc.submit_points(np.zeros((5, 7), np.float32))  # wrong d
        svc.submit_points(np.zeros((5, 3), np.float32))
        svc.drain(timeout=120)


# ---------------------------------------------------------------------------
# lifecycle + validation
# ---------------------------------------------------------------------------

def test_assign_before_any_centers_fails():
    with KCenterService(4, 3) as svc:
        with pytest.raises(RuntimeError, match="no centers"):
            svc.assign(np.zeros((1, 3), np.float32), timeout=60)


def test_closed_service_rejects_work():
    svc = KCenterService(4, 3)
    svc.close()
    with pytest.raises(RuntimeError):
        svc.assign(np.zeros((1, 3), np.float32))
    with pytest.raises(RuntimeError):
        svc.submit_points(np.zeros((1, 3), np.float32))
    svc.close()                                    # idempotent


def test_query_validation():
    with KCenterService(4, 3) as svc:
        with pytest.raises(ValueError):
            svc.assign_async(np.zeros((2, 5), np.float32))   # wrong d
        with pytest.raises(ValueError):
            svc.assign_async(np.zeros((0, 3), np.float32))   # empty
        t = svc.assign_async(np.zeros(3, np.float32))        # (d,) promotes
        assert t.q.shape == (1, 3)
        svc.close()


# ---------------------------------------------------------------------------
# streaming insertion tail (core/streaming.py perf fix)
# ---------------------------------------------------------------------------

def test_stream_update_tail_validation():
    st = stream_init(4, 3)
    with pytest.raises(ValueError, match="tail"):
        stream_update(st, np.zeros((2, 3), np.float32), tail="gpu")


@pytest.mark.parametrize("tail", ["host", "device"])
def test_tail_invariants(tail):
    """Both tails keep the doubling invariants: ≤ k centers at rest,
    pairwise separation > r."""
    k, d = 6, 4
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(800, d)).astype(np.float32)
    pts *= np.linspace(1.0, 40.0, 800, dtype=np.float32)[:, None]
    st = stream_init(k, d)
    for i in range(0, 800, 100):
        st = stream_update(st, pts[i:i + 100], tail=tail)
        assert st.count <= k + 1
    centers, r = stream_result(st)
    assert centers.shape[0] <= k
    if centers.shape[0] > 1 and r > 0:
        diff = centers[:, None, :] - centers[None, :, :]
        dist = np.sqrt((diff ** 2).sum(-1))
        np.fill_diagonal(dist, np.inf)
        assert dist.min() > r


def test_host_tail_matches_device_tail_on_separated_data():
    """On well-separated clustered data (decision margins ≫ 1 ulp) the two
    tails walk the identical doubling trajectory."""
    k, d = 8, 4
    pts = _clustered(2000, k, d, seed=5)
    st_h = stream_init(k, d)
    st_d = stream_init(k, d)
    for i in range(0, 2000, 250):
        st_h = stream_update(st_h, pts[i:i + 250], tail="host")
        st_d = stream_update(st_d, pts[i:i + 250], tail="device")
    assert st_h.count == st_d.count
    assert st_h.r == st_d.r
    assert np.array_equal(st_h.centers[:st_h.count],
                          st_d.centers[:st_d.count])


def test_host_tail_covers_every_streamed_point():
    """8-approx guarantee proxy: every point ends within 4r of a center."""
    k, d = 6, 3
    rng = np.random.default_rng(4)
    pts = rng.normal(size=(1500, d)).astype(np.float32)
    pts *= np.linspace(1.0, 30.0, 1500, dtype=np.float32)[:, None]
    st = stream_init(k, d)
    for i in range(0, 1500, 300):
        st = stream_update(st, pts[i:i + 300], tail="host")
    centers, r = stream_result(st)
    _, d2 = _offline(pts, centers)
    assert float(np.sqrt(d2).max()) <= 4.0 * r + 1e-4
