"""Unit tests for the dry-run HLO collective parser (trip-count math)."""
import textwrap

from repro.launch.dryrun import (_split_computations, _type_bytes,
                                 parse_collectives)

HLO = textwrap.dedent("""\
    HloModule jit_step

    %wide.body (arg: (s32[], bf16[4,8])) -> (s32[], bf16[4,8]) {
      %ar = bf16[4,8]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
      %ag = bf16[4,8]{1,0} all-gather(%y), replica_groups=[4,8]<=[32]
      ROOT %t = (s32[], bf16[4,8]) tuple(%i, %ar)
    }

    %wide.cond (arg: (s32[], bf16[4,8])) -> pred[] {
      %gte = s32[] get-tuple-element(%arg), index=0
      %c = s32[] constant(24)
      ROOT %cmp = pred[] compare(%gte, %c), direction=LT
    }

    ENTRY %main (p0: bf16[4,8]) -> bf16[4,8] {
      %w = (s32[], bf16[4,8]) while(%init), condition=%wide.cond, body=%wide.body
      %ar2 = f32[16]{0} all-reduce(%z), replica_groups={{0,1}}
      ROOT %out = bf16[4,8] get-tuple-element(%w), index=1
    }
""")


def test_type_bytes():
    assert _type_bytes("bf16[4,8]{1,0}") == 64
    assert _type_bytes("f32[16]{0}") == 64
    assert _type_bytes("(bf16[4,8]{1,0}, f32[2,2]{1,0})") == 64 + 16


def test_split_computations_handles_tuple_signatures():
    comps, entry = _split_computations(HLO)
    assert entry == "main"
    assert "wide.body" in comps and "wide.cond" in comps


def test_trip_count_multiplication():
    total, per_op = parse_collectives(HLO)
    # body: all-reduce 64 B + all-gather operand 64/8 B, × 24 trips;
    # entry: all-reduce 64 B × 1
    assert per_op["all-reduce"]["count"] == 24 + 1
    assert per_op["all-reduce"]["operand_bytes"] == 24 * 64 + 64
    assert per_op["all-gather"]["count"] == 24
    assert per_op["all-gather"]["operand_bytes"] == 24 * (64 // 8)
    assert total == 24 * 64 + 64 + 24 * 8
