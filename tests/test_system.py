"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, live_cells


def test_paper_pipeline_end_to_end():
    """The full paper flow: generate clustered data, run all three
    algorithms, verify the paper's qualitative claims hold (GON≈MRG≈EIM
    values; k>=k' collapses the GAU radius)."""
    from repro.core import eim, gonzalez, mrg_sim
    from repro.data import gau
    pts = jnp.asarray(gau(20_000, k_prime=10, seed=0))
    vals = {}
    for name, fn in (
            ("gon", lambda: gonzalez(pts, 10).radius2),
            ("mrg", lambda: mrg_sim(pts, 10, m=20, capacity=4000).radius2),
            ("eim", lambda: eim(pts, 10, jax.random.PRNGKey(0)).radius2)):
        vals[name] = float(jnp.sqrt(fn()))
    # with k = k' = 10 all algorithms must find the cluster structure:
    # radius ~ sigma-scale, not side-scale (paper Tables 2/4 behavior)
    for name, v in vals.items():
        assert v < 5.0, vals
    # parallel variants within 4x of the sequential baseline (factor bound)
    assert vals["mrg"] <= 4 * vals["gon"] + 1e-6
    assert vals["eim"] <= 10 * vals["gon"] + 1e-6


def test_coreset_curation_integration():
    """Framework integration: embeddings -> k-center coreset -> curated
    batch indices, weights partition the dataset."""
    from repro.core import select_coreset
    rng = np.random.default_rng(0)
    emb = jnp.asarray(rng.normal(size=(500, 32)).astype(np.float32))
    cs = select_coreset(emb, 16)
    assert cs.indices.shape == (16,)
    assert int(jnp.sum(cs.weights)) == 500
    assert float(cs.radius2) > 0


def test_short_training_run_descends_and_checkpoints(tmp_path):
    from repro.launch.train import train_loop
    from repro.models import init_params
    from repro.train.metrics import make_eval_fn
    cfg = get_config("granite_3_2b", smoke=True)
    # Descent is asserted on a *fixed* held-out eval set: per-step train
    # losses come from different batches whose intrinsic difficulty varies
    # by more than 12 steps of learning moves the loss, so comparing
    # hist[-1] to hist[0] measures batch luck, not learning.
    eval_fn = make_eval_fn(cfg, batch_size=4, seq_len=32, seed=0)
    base = eval_fn(init_params(jax.random.PRNGKey(0), cfg))["eval_loss"]
    state, hist = train_loop(cfg, steps=12, batch_size=4, seq_len=32,
                             ckpt_dir=str(tmp_path), ckpt_every=6,
                             log_every=100)
    assert eval_fn(state["params"])["eval_loss"] < base
    assert len(hist) == 12
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 12


def test_all_cells_enumerate():
    cells = live_cells()
    assert len(cells) == 32  # 10 archs × 3 shapes + 2 long-context
    assert ("mamba2_370m", "long_500k") in cells
    assert ("qwen2_0_5b", "long_500k") not in cells


def test_input_specs_are_abstract():
    """input_specs never allocates device memory (ShapeDtypeStruct only)."""
    from repro.launch.specs import input_specs
    cfg, specs = input_specs("qwen2_0_5b", SHAPES["train_4k"])
    leaves = jax.tree_util.tree_leaves(specs)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    assert any(l.shape[:2] == (256, 4096) for l in leaves
               if hasattr(l, "shape") and len(l.shape) == 2)
