"""End-to-end dry-run smoke: one real cell lowers + compiles on the
production mesh (512 placeholder devices) in a subprocess, and the
artifact contains all roofline inputs.

This covers deliverable (e) in-suite; the full 64-cell sweep runs via
experiments/run_sweep.sh.
"""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.parametrize("arch,shape,mesh", [
    ("qwen2_0_5b", "decode_32k", "single"),
    ("mamba2_370m", "long_500k", "multi"),
])
def test_dryrun_cell_compiles(tmp_path, arch, shape, mesh):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    mesh_name = "pod16x16" if mesh == "single" else "pod2x16x16"
    path = tmp_path / mesh_name / f"{arch}__{shape}.json"
    rec = json.loads(path.read_text())
    assert rec["chips"] == (256 if mesh == "single" else 512)
    assert rec["memory_analysis"]["temp_size_in_bytes"] >= 0
    assert rec["roofline_terms_s"]["memory_s"] > 0
    assert "collectives_per_device" in rec
