"""Training substrate tests: loss descent, microbatch equivalence,
optimizers, schedules, chunked CE."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import model_batch
from repro.optim import adafactor, adamw, make_schedule
from repro.train import (chunked_softmax_xent, cross_entropy,
                         init_train_state, make_train_step)
from repro.train.step import make_loss_fn


def test_loss_decreases_smoke_lm():
    cfg = get_config("qwen2_0_5b", smoke=True)
    opt = adamw(make_schedule("cosine", peak=1e-2, warmup=3, total=50))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for s in range(40):
        batch = {k: jnp.asarray(v)
                 for k, v in model_batch(cfg, 8, 32, step=s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_microbatched_grads_match_full_batch():
    cfg = get_config("olmo_1b", smoke=True)
    opt = adamw(make_schedule("constant", peak=1e-3))
    loss_fn = make_loss_fn(cfg)
    state = init_train_state(jax.random.PRNGKey(1), cfg, opt)
    batch = {k: jnp.asarray(v) for k, v in model_batch(cfg, 8, 16).items()}
    g_full = jax.grad(lambda p: loss_fn(p, batch)[0])(state["params"])

    step1 = make_train_step(cfg, opt, num_microbatches=1)
    step4 = make_train_step(cfg.replace(microbatches=4), opt)
    s1, m1 = jax.jit(step1)(state, batch)
    state2 = init_train_state(jax.random.PRNGKey(1), cfg, opt)
    s4, m4 = jax.jit(step4)(state2, batch)
    # same loss and same resulting params (f32 accumulate, mean-of-means
    # equals full mean here because microbatches are equal-sized)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    l1 = jax.tree_util.tree_leaves(s1["params"])
    l4 = jax.tree_util.tree_leaves(s4["params"])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


def test_chunked_ce_matches_dense_ce():
    rng = np.random.default_rng(0)
    B, S, D, V = 2, 8, 16, 64
    hidden = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(D, V)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, V, (B, S)).astype(np.int32))
    cfg = get_config("olmo_1b", smoke=True)
    dense = cross_entropy(jnp.einsum("bsd,dv->bsv", hidden, w), labels)
    chunked = chunked_softmax_xent(hidden, w, labels, cfg, chunk=4)
    np.testing.assert_allclose(float(dense), float(chunked), rtol=1e-5)


def _quad_min(opt, steps=120):
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state = opt.update(grads, state, params)
        state.pop("grad_norm", None)
        state.pop("lr", None)
    return float(jnp.sum((params["w"] - target) ** 2))


def test_adamw_minimizes_quadratic():
    opt = adamw(lambda s: 5e-2, weight_decay=0.0)
    assert _quad_min(opt) < 1e-2


def test_adafactor_minimizes_quadratic():
    opt = adafactor(lambda s: 3e-1)
    assert _quad_min(opt, steps=400) < 0.1


def test_adafactor_state_is_factored():
    opt = adafactor(lambda s: 1e-3)
    params = {"w": jnp.zeros((32, 16)), "b": jnp.zeros((16,))}
    st = opt.init(params)
    shapes = [tuple(v.shape) for leaf in st["v"] for v in leaf.values()]
    assert (16,) in shapes and (32,) in shapes  # vr/vc, no (32,16)


def test_wsd_schedule_shape():
    f = make_schedule("wsd", peak=1.0, warmup=10, total=100,
                      decay_frac=0.2)
    assert float(f(0)) < 0.2
    assert np.isclose(float(f(50)), 1.0)
    assert float(f(99)) < 0.2


def test_moe_aux_loss_included():
    cfg = get_config("dbrx_132b", smoke=True)
    loss_fn = make_loss_fn(cfg, moe_aux_weight=0.0)
    loss_fn_aux = make_loss_fn(cfg, moe_aux_weight=10.0)
    from repro.models import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in model_batch(cfg, 2, 8).items()}
    l0 = float(loss_fn(params, batch)[0])
    l1 = float(loss_fn_aux(params, batch)[0])
    assert l1 > l0  # balancing loss is positive
