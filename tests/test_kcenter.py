"""Correctness tests for the paper's algorithms (GON / MRG / EIM)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (brute_force_opt, eim, eim_sample,
                        gonzalez, mrg_sim, plan_rounds)
from repro.kernels import ref


def _pts(n, d=2, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


class TestGonzalez:
    def test_two_approx_vs_bruteforce(self):
        for seed in range(4):
            pts = _pts(14, seed=seed)
            for k in (2, 3, 4):
                opt = brute_force_opt(pts, k)
                got = float(jnp.sqrt(gonzalez(jnp.asarray(pts), k).radius2))
                assert got <= 2.0 * opt + 1e-5, (seed, k, got, opt)

    def test_anti_chain_invariant(self):
        # Gonzalez centers are pairwise >= covering radius apart.
        pts = _pts(300, 3, seed=1)
        res = gonzalez(jnp.asarray(pts), 10)
        pd = ref.pairwise_dist2(res.centers, res.centers)
        pd = pd + jnp.eye(10) * 1e9
        assert float(jnp.min(pd)) >= float(res.radius2) - 1e-4

    def test_radius_monotone_in_k(self):
        pts = jnp.asarray(_pts(200, seed=2))
        radii = [float(gonzalez(pts, k).radius2) for k in (2, 4, 8, 16, 32)]
        for a, b in zip(radii, radii[1:]):
            assert b <= a + 1e-6

    def test_masked_equals_subset(self):
        pts = _pts(100, seed=3)
        mask = np.zeros(100, bool)
        mask[::2] = True
        r_masked = gonzalez(jnp.asarray(pts), 5, mask=jnp.asarray(mask))
        r_subset = gonzalez(jnp.asarray(pts[mask]), 5)
        assert np.isclose(float(r_masked.radius2),
                          float(r_subset.radius2), rtol=1e-5)

    def test_min_d2_covers_all(self):
        pts = jnp.asarray(_pts(150, seed=4))
        res = gonzalez(pts, 6)
        _, d2 = ref.assign_nearest(pts, res.centers), None
        idx, d2 = ref.assign_nearest(pts, res.centers)
        assert np.allclose(np.asarray(res.min_d2), np.asarray(d2),
                           atol=1e-4)


class TestMRG:
    def test_four_approx_vs_bruteforce(self):
        for seed in range(3):
            pts = _pts(16, seed=seed + 10)
            opt = brute_force_opt(pts, 3)
            r = mrg_sim(jnp.asarray(pts), 3, m=4, capacity=100)
            assert float(jnp.sqrt(r.radius2)) <= 4.0 * opt + 1e-5

    def test_two_rounds_when_capacity_allows(self):
        pts = _pts(500, seed=5)
        r = mrg_sim(jnp.asarray(pts), 5, m=10, capacity=1000)
        assert r.rounds == 2

    def test_multiround_when_capacity_small(self):
        pts = _pts(600, seed=6)
        # k*m = 80 > capacity 30 forces extra rounds
        r = mrg_sim(jnp.asarray(pts), 8, m=10, capacity=30)
        assert r.rounds > 2
        # quality still bounded: 2(i+1)-approx => radius <= 2*rounds*GON
        g = gonzalez(jnp.asarray(pts), 8)
        assert float(r.radius2) <= (2 * r.rounds) ** 2 * float(g.radius2) + 1e-4

    def test_plan_rounds_matches_paper(self):
        # paper §3.2: n/m<=c and k*m<=c => 2 rounds
        assert plan_rounds(10 ** 6, 50, 25, 20_000) == 2
        # k*m > c forces more rounds
        assert plan_rounds(10 ** 6, 50, 1000, 20_000) == 3
        # k > c infeasible
        with pytest.raises(ValueError):
            plan_rounds(10 ** 6, 50, 30_000, 20_000)


class TestEIM:
    def test_small_n_degenerates_to_gon(self):
        # paper Fig 4: when threshold >= n the while loop never runs
        pts = jnp.asarray(_pts(500, seed=7))
        e = eim(pts, 8, jax.random.PRNGKey(0))
        g = gonzalez(pts, 8)
        assert not bool(e.sample.sampled)
        assert np.isclose(float(e.radius2), float(g.radius2), rtol=1e-5)

    def test_sampling_path_terminates_and_bounded(self):
        # n large enough that the threshold (4/eps)k n^eps ln n < n
        pts = jnp.asarray(_pts(20_000, seed=8))
        e = eim(pts, 4, jax.random.PRNGKey(1), eps=0.1, phi=8.0)
        assert bool(e.sample.sampled)
        assert int(e.sample.iters) >= 1
        g = gonzalez(pts, 4)
        # w.s.p. 10-approx; GON >= OPT so this is a (loose) sanity bound
        assert float(jnp.sqrt(e.radius2)) <= \
            10.0 * float(jnp.sqrt(g.radius2)) + 1e-5

    def test_sample_mask_is_superset_of_sampled_s(self):
        pts = jnp.asarray(_pts(20_000, seed=9))
        s = eim_sample(pts, 4, jax.random.PRNGKey(2), eps=0.1)
        assert bool(jnp.all(~s.s_mask | s.sample_mask))

    def test_phi_monotone_runtime_iterations(self):
        # smaller phi -> lower pivot threshold -> more removed per iter
        pts = jnp.asarray(_pts(20_000, seed=10))
        it_small = int(eim_sample(pts, 4, jax.random.PRNGKey(3),
                                  eps=0.1, phi=1.0).iters)
        it_big = int(eim_sample(pts, 4, jax.random.PRNGKey(3),
                                eps=0.1, phi=8.0).iters)
        assert it_small <= it_big + 1

    def test_termination_fix_sampled_points_leave_r(self):
        pts = jnp.asarray(_pts(20_000, seed=11))
        s = eim_sample(pts, 4, jax.random.PRNGKey(4), eps=0.1)
        # every point is in exactly one of {S, R_final, removed}
        assert int(s.overflow) == 0
