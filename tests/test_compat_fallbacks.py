"""Pin repro.compat's feature-detection *fallback* branches.

The shim resolves every drifting jax API at import time via hasattr
probes. The happy branch for the running jax line is exercised by the
whole suite; these tests force each detection to MISS — by deleting the
probed symbol and importing a fresh copy of the module — and pin that
the legacy branch still produces the same public surface (and, for the
global-assembly fallback, bitwise-identical arrays).

A fresh module instance is loaded per test via spec_from_file_location:
``importlib.reload`` would mutate the singleton other modules hold
references to, leaking the monkeypatch beyond the test.
"""
import importlib.util
from pathlib import Path

import jax
import jax.sharding
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat as canonical

COMPAT_PATH = (Path(__file__).resolve().parents[1]
               / "src" / "repro" / "compat.py")

_counter = [0]


def load_fresh_compat():
    """Import a brand-new compat module instance under current jax attrs."""
    _counter[0] += 1
    spec = importlib.util.spec_from_file_location(
        f"_compat_fresh_{_counter[0]}", COMPAT_PATH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def one_device_mesh(mod):
    return mod.make_mesh(np.array(jax.devices()[:1]), ("data",))


def test_fresh_load_matches_canonical_flags():
    mod = load_fresh_compat()
    assert mod.HAS_TOP_LEVEL_SHARD_MAP == canonical.HAS_TOP_LEVEL_SHARD_MAP
    assert mod.HAS_AXIS_TYPE == canonical.HAS_AXIS_TYPE
    assert mod.HAS_SET_MESH == canonical.HAS_SET_MESH
    assert mod.HAS_GLOBAL_ASSEMBLY == canonical.HAS_GLOBAL_ASSEMBLY


def test_missing_top_level_shard_map_uses_experimental(monkeypatch):
    monkeypatch.delattr(jax, "shard_map", raising=False)
    pytest.importorskip(
        "jax.experimental.shard_map",
        reason="this jax line has neither top-level nor experimental "
               "shard_map")
    mod = load_fresh_compat()
    assert mod.HAS_TOP_LEVEL_SHARD_MAP is False
    mesh = one_device_mesh(mod)
    f = mod.shard_map(mesh=mesh, in_specs=P(), out_specs=P(),
                      check_replication=False)(lambda x: x * 2.0)
    x = np.arange(4.0, dtype=np.float32)
    np.testing.assert_array_equal(np.asarray(f(x)), x * 2.0)


def test_missing_axis_type_builds_legacy_mesh(monkeypatch):
    monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
    mod = load_fresh_compat()
    assert mod.HAS_AXIS_TYPE is False
    mesh = one_device_mesh(mod)
    assert isinstance(mesh, jax.sharding.Mesh)
    assert mesh.axis_names == ("data",)
    assert mesh.devices.shape == (1,)


def test_missing_set_mesh_uses_legacy_context(monkeypatch):
    monkeypatch.delattr(jax, "set_mesh", raising=False)
    mod = load_fresh_compat()
    assert mod.HAS_SET_MESH is False
    mesh = one_device_mesh(mod)
    with mod.set_mesh(mesh) as m:
        assert m is mesh
        # the legacy `with mesh:` resource env is active: a NamedSharding
        # built under it still resolves against this mesh
        s = jax.sharding.NamedSharding(mesh, P("data"))
        assert s.mesh.axis_names == ("data",)


def test_missing_global_assembly_falls_back_to_device_put(monkeypatch):
    pieces = [np.arange(12, dtype=np.float32).reshape(4, 3) + 100 * i
              for i in range(len(jax.devices()[:1]))]
    # canonical (assembly-API) reference, computed before the symbol is
    # deleted — the fallback must be bitwise-identical to it
    ref = np.asarray(canonical.global_array_from_shards(
        one_device_mesh(canonical), P("data"), pieces))
    monkeypatch.delattr(jax, "make_array_from_single_device_arrays",
                        raising=False)
    mod = load_fresh_compat()
    assert mod.HAS_GLOBAL_ASSEMBLY is False
    mesh = one_device_mesh(mod)
    out = mod.global_array_from_shards(mesh, P("data"), pieces)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.concatenate(pieces, axis=0))
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_shard_map_replication_kwarg_resolved():
    # whatever the line, the resolver must land on a known kwarg (or
    # None on a hypothetical future line that dropped both)
    assert canonical._CHECK_KW in ("check_vma", "check_rep", None)
    mesh = one_device_mesh(canonical)
    f = canonical.shard_map(lambda x: x + 1.0, mesh=mesh, in_specs=P(),
                            out_specs=P(), check_replication=False)
    x = np.ones((3,), np.float32)
    np.testing.assert_array_equal(np.asarray(f(x)), x + 1.0)


def test_global_assembly_rejects_none_local_piece():
    # Multi-process callers may pass None for *remote* shards only; on a
    # single process every shard is addressable, so any None must raise.
    # (the addressable-but-None branch needs >= 2 devices and is pinned
    # by the multi-process assembly scenario in tests/distributed/)
    mesh = one_device_mesh(canonical)
    good = np.zeros((4, 3), np.float32)
    with pytest.raises(ValueError, match="all pieces are None"):
        canonical.global_array_from_shards(mesh, P("data"), [None])
    with pytest.raises(ValueError, match="all pieces are None"):
        canonical.global_array_from_shards(mesh, P("data"), [None] * 4)
    with pytest.raises(ValueError, match="expected"):
        canonical.global_array_from_shards(
            mesh, P("data"), [good, np.zeros((2, 3), np.float32)])
    # a present piece still assembles when *it* is the only shard
    out = canonical.global_array_from_shards(mesh, P("data"), [good])
    np.testing.assert_array_equal(np.asarray(out), good)


def test_global_assembly_fallback_rejects_none(monkeypatch):
    # The host-concatenate fallback needs every row on this host — a
    # None (remote) piece must be a hard error, not a silent zero-fill.
    monkeypatch.delattr(jax, "make_array_from_single_device_arrays",
                        raising=False)
    mod = load_fresh_compat()
    assert mod.HAS_GLOBAL_ASSEMBLY is False
    mesh = one_device_mesh(mod)
    good = np.zeros((4, 3), np.float32)
    with pytest.raises(RuntimeError, match="needs every piece"):
        mod.global_array_from_shards(mesh, P("data"), [good, None])


def test_single_process_distributed_helpers():
    # On a one-process runtime the cross-process primitives degenerate to
    # identities — these are the exact code paths the single-process
    # executors keep using after the multi-process refactor.
    assert canonical.process_count() >= 1
    assert canonical.process_index() == 0
    x = np.arange(12, dtype=np.float32).reshape(4, 3)
    np.testing.assert_array_equal(canonical.fetch_global(x), x)
    ex = canonical.exchange_host(x)
    assert ex.shape == (1, 4, 3)
    np.testing.assert_array_equal(ex[0], x)
    mesh = one_device_mesh(canonical)
    rep = canonical.replicated_array(mesh, x)
    np.testing.assert_array_equal(np.asarray(rep), x)
    assert canonical.local_shard_indices(mesh, P("data"), 1) == [0]
    # enable_cpu_collectives is idempotent and reports availability
    assert canonical.enable_cpu_collectives() in (True, False)
