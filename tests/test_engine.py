"""Chunked-engine parity: streaming row-blocks must not change results.

Contract (kernels/engine.py): for every op and every chunk size — including
chunk = 1, chunk that doesn't divide n, and chunk > n — the chunked result
equals the un-chunked reference. On the ref path elementwise outputs are
bitwise-equal (identical per-row arithmetic, only the iteration structure
changes); the Pallas path is validated to kernel tolerance.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gonzalez
from repro.core.mrg import mrg_sim
from repro.kernels import engine, ops, ref

CHUNKS = [1, 3, 8, 100, 512, 999, 1000, 4096]   # vs n=1000: tiny, odd,
                                                # divisible, ==n, >n


def _data(n=1000, m=13, d=7, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    md = jnp.asarray(rng.uniform(0.5, 20, size=(n,)).astype(np.float32))
    return x, c, md


@pytest.mark.parametrize("chunk", CHUNKS)
def test_assign_nearest_chunk_parity_ref(chunk):
    x, c, _ = _data()
    i0, d0 = ref.assign_nearest(x, c)
    i1, d1 = ops.assign_nearest(x, c, impl="ref", chunk=chunk)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("chunk", CHUNKS)
def test_fused_min_argmax_chunk_parity_ref(chunk):
    x, c, md = _data(seed=1)
    nm0, fv0, fi0 = ref.fused_min_argmax(x, c[0], md)
    nm1, fv1, fi1 = ops.fused_min_argmax(x, c[0], md, impl="ref",
                                         chunk=chunk)
    np.testing.assert_array_equal(np.asarray(nm0), np.asarray(nm1))
    assert int(fi0) == int(fi1)
    assert float(fv0) == float(fv1)


@pytest.mark.parametrize("chunk", CHUNKS)
def test_pairwise_dist2_chunk_parity_ref(chunk):
    x, c, _ = _data(seed=2)
    p0 = ref.pairwise_dist2(x, c)
    p1 = ops.pairwise_dist2(x, c, impl="ref", chunk=chunk)
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))


def test_fused_min_argmax_cross_chunk_tie_breaks_to_first():
    # Two exactly-equal global maxima in different chunks: the chunked
    # reduction must return the first index, like jnp.argmax.
    x = jnp.zeros((8, 2), jnp.float32)
    md = jnp.asarray([1.0, 5.0, 2.0, 3.0, 1.0, 5.0, 0.5, 0.5], jnp.float32)
    c = jnp.asarray([100.0, 100.0], jnp.float32)  # far: min stays md
    _, _, fi = ops.fused_min_argmax(x, c, md, impl="ref", chunk=2)
    assert int(fi) == 1


@pytest.mark.parametrize("chunk", [1, 7, 64, 2000])
def test_assign_nearest_chunk_parity_pallas(chunk):
    x, c, _ = _data(n=257, m=9, seed=3)
    i0, d0 = ref.assign_nearest(x, c)
    i1, d1 = ops.assign_nearest(x, c, impl="pallas", chunk=chunk, bn=64,
                                bm=8)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-4,
                               atol=1e-4)
    # ties can legitimately differ; compare indices where nearest is unique
    d2 = np.asarray(ref.pairwise_dist2(x, c))
    part = np.partition(d2, 1, axis=1)
    unique = part[:, 1] - part[:, 0] > 1e-5
    assert (np.asarray(i0)[unique] == np.asarray(i1)[unique]).all()


@pytest.mark.parametrize("chunk", CHUNKS)
def test_argmin_dist2_over_rows_chunk_parity_ref(chunk):
    x, c, _ = _data(seed=6)
    i0, _ = ref.assign_nearest(c, x)   # unchunked oracle: (m,) over n rows
    i1 = ops.argmin_dist2_over_rows(x, c, impl="ref", chunk=chunk)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_eim_chunk_invariant():
    import jax
    from repro.core import eim
    x, _, _ = _data(n=2000, seed=7)
    r0 = eim(x, 5, jax.random.PRNGKey(0), impl="ref")
    r1 = eim(x, 5, jax.random.PRNGKey(0), impl="ref", chunk=123)
    np.testing.assert_array_equal(np.asarray(r0.centers),
                                  np.asarray(r1.centers))
    assert float(r0.radius2) == float(r1.radius2)


def test_coreset_chunk_invariant():
    from repro.core import select_coreset
    x, _, _ = _data(n=500, d=16, seed=8)
    c0 = select_coreset(x, 8, impl="ref")
    c1 = select_coreset(x, 8, impl="ref", chunk=77)
    np.testing.assert_array_equal(np.asarray(c0.indices),
                                  np.asarray(c1.indices))
    np.testing.assert_array_equal(np.asarray(c0.weights),
                                  np.asarray(c1.weights))


def test_memory_budget_resolves_and_matches():
    x, c, _ = _data()
    n, d = x.shape
    m = c.shape[0]
    budget = 64 * 1024
    chunk = engine.resolve_chunk(n, m, d, memory_budget=budget)
    assert 1 <= chunk < n                       # budget actually forces
    assert 4 * chunk * (m + d) + 4 * m * d <= budget  # streaming model holds
    i0, d0 = ref.assign_nearest(x, c)
    i1, d1 = ops.assign_nearest(x, c, impl="ref", memory_budget=budget)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_memory_budget_too_small_raises():
    with pytest.raises(ValueError):
        engine.resolve_chunk(1000, 1000, 128, memory_budget=1024)


def test_chunk_invalid_raises():
    with pytest.raises(ValueError):
        engine.resolve_chunk(10, 3, 2, chunk=0)


@pytest.mark.parametrize("chunk", [1, 37, 999, 1000, 4096])
def test_gonzalez_radius_invariant_under_chunk(chunk):
    x, _, _ = _data(seed=4)
    g0 = gonzalez(x, 8, impl="ref")
    g1 = gonzalez(x, 8, impl="ref", chunk=chunk)
    np.testing.assert_array_equal(np.asarray(g0.indices),
                                  np.asarray(g1.indices))
    assert float(g0.radius2) == float(g1.radius2)


def test_mrg_sim_chunk_invariant():
    x, _, _ = _data(seed=5)
    r0 = mrg_sim(x, 6, m=10, impl="ref")
    r1 = mrg_sim(x, 6, m=10, impl="ref", chunk=33)
    np.testing.assert_array_equal(np.asarray(r0.centers),
                                  np.asarray(r1.centers))
    assert float(r0.radius2) == float(r1.radius2)


# ---------------------------------------------------------------------------
# source folds (engine.py): block-streamed ops over a PointSource
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows", [1, 77, 256, 1000, 4096])
def test_fold_min_d2_matches_assign_max(rows):
    from repro.data import HostSource
    x, c, _ = _data(seed=9)
    _, d2 = ref.assign_nearest(x, c)
    got = ops.fold_min_d2(HostSource(np.asarray(x)), c, impl="ref",
                          block_rows=rows)
    assert float(jnp.max(d2)) == float(got)


@pytest.mark.parametrize("rows", [1, 77, 256, 1000, 4096])
def test_assign_nearest_source_concat_parity(rows):
    from repro.data import HostSource
    x, c, _ = _data(seed=10)
    i0, d0 = ref.assign_nearest(x, c)
    parts = list(ops.assign_nearest_source(HostSource(np.asarray(x)), c,
                                           impl="ref", block_rows=rows))
    i1 = np.concatenate([np.asarray(i) for i, _ in parts])
    d1 = np.concatenate([np.asarray(d) for _, d in parts])
    np.testing.assert_array_equal(np.asarray(i0), i1)
    np.testing.assert_array_equal(np.asarray(d0), d1)


@pytest.mark.parametrize("rows", [1, 77, 256, 1000, 4096])
def test_argmin_dist2_over_source_parity(rows):
    from repro.data import HostSource
    x, c, _ = _data(seed=11)
    i0, _ = ref.assign_nearest(c, x)   # unchunked oracle: (m,) over n rows
    i1 = ops.argmin_dist2_over_source(HostSource(np.asarray(x)), c,
                                      impl="ref", block_rows=rows)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_resolve_block_rows_model():
    # explicit rows win, clipped to n
    assert engine.resolve_block_rows(100, 8, block_rows=7) == 7
    assert engine.resolve_block_rows(100, 8, block_rows=500) == 100
    # budget model: (1+prefetch)·4·rows·(d+1) <= budget (the consumed block
    # plus the prefetch ring; default prefetch=2 => 3 resident blocks)
    rows = engine.resolve_block_rows(10 ** 9, 7, memory_budget=1 << 20)
    assert 12 * rows * 8 <= 1 << 20 < 12 * (rows + 1) * 8
    # prefetch=1 recovers the PR-2 double-buffer model
    rows1 = engine.resolve_block_rows(10 ** 9, 7, memory_budget=1 << 20,
                                      prefetch=1)
    assert 8 * rows1 * 8 <= 1 << 20 < 8 * (rows1 + 1) * 8
    with pytest.raises(ValueError):
        engine.resolve_block_rows(100, 8, block_rows=0)
    with pytest.raises(ValueError):
        engine.resolve_block_rows(100, 1024, memory_budget=64)
    with pytest.raises(ValueError):
        engine.resolve_block_rows(100, 8, memory_budget=1 << 20, prefetch=0)


# ---------------------------------------------------------------------------
# fused streamed tiles (kernels/fused_stream.py via engine dispatch):
# impl="pallas" (interpret on CPU) must be BITWISE the ref oracle — the
# rows-only tiling contract, not an allclose approximation.
# ---------------------------------------------------------------------------

STREAM_ROWS = [1, 8, 77, 256, 999, 1000, 4096]   # ragged tails, sub-sublane,
                                                 # exact tiles, multi-tile


@pytest.mark.parametrize("rows", STREAM_ROWS)
def test_fold_min_d2_pallas_bitwise(rows):
    from repro.data import HostSource
    x, c, _ = _data(seed=20)
    r0 = ops.fold_min_d2(HostSource(np.asarray(x)), c, impl="ref",
                         block_rows=rows)
    r1 = ops.fold_min_d2(HostSource(np.asarray(x)), c, impl="pallas",
                         block_rows=rows)
    assert float(r0) == float(r1)


@pytest.mark.parametrize("rows", STREAM_ROWS)
def test_assign_nearest_source_pallas_bitwise(rows):
    from repro.data import HostSource
    x, c, _ = _data(seed=21)

    def cat(impl):
        parts = list(ops.assign_nearest_source(
            HostSource(np.asarray(x)), c, impl=impl, block_rows=rows))
        return (np.concatenate([np.asarray(i) for i, _ in parts]),
                np.concatenate([np.asarray(d) for _, d in parts]))

    i0, d0 = cat("ref")
    i1, d1 = cat("pallas")
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(d0, d1)


@pytest.mark.parametrize("rows", STREAM_ROWS)
def test_argmin_dist2_over_source_pallas_bitwise(rows):
    from repro.data import HostSource
    x, c, _ = _data(seed=22)
    i0 = ops.argmin_dist2_over_source(HostSource(np.asarray(x)), c,
                                      impl="ref", block_rows=rows)
    i1 = ops.argmin_dist2_over_source(HostSource(np.asarray(x)), c,
                                      impl="pallas", block_rows=rows)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_argmin_source_pallas_cross_block_tie_first():
    # The nearest row to each center is duplicated in a *later* block:
    # first-occurrence must win, exactly like jnp.argmin over the stream.
    from repro.data import HostSource
    rng = np.random.default_rng(23)
    x = rng.normal(size=(40, 3)).astype(np.float32)
    x[31] = x[7]          # block 2 duplicates block 0's row 7
    c = (x[7] + 1e-3).reshape(1, 3).astype(np.float32)
    for impl in ("ref", "pallas"):
        i = ops.argmin_dist2_over_source(HostSource(x), c, impl=impl,
                                         block_rows=16)
        assert int(np.asarray(i)[0]) == 7, impl


@pytest.mark.parametrize("chunk", [None, 8, 100, 999])
@pytest.mark.parametrize("rank", [1, 5, 64])
def test_filter_tile_update_pallas_bitwise(rank, chunk):
    x, c, md = _data(seed=24)
    h = np.asarray(md) > 10.0          # a nontrivial H mask
    d0, t0 = engine.filter_tile_update(x, c, md, jnp.asarray(h),
                                       rank=rank, impl="ref", chunk=chunk)
    d1, t1 = engine.filter_tile_update(x, c, md, jnp.asarray(h),
                                       rank=rank, impl="pallas", chunk=chunk)
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
    np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))


def test_filter_tile_update_rank_exceeds_rows():
    # rank > rows: surplus slots fill with the -BIG sentinel on both paths.
    x, c, md = _data(n=5, seed=25)
    h = jnp.ones((5,), bool)
    d0, t0 = engine.filter_tile_update(x, c, md, h, rank=200, impl="ref")
    d1, t1 = engine.filter_tile_update(x, c, md, h, rank=200, impl="pallas")
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))
    np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))


@pytest.mark.parametrize("chunk", [None, 64])
def test_eim_filter_block_pallas_bitwise(chunk):
    x, c, md = _data(seed=26)
    h = jnp.asarray(np.asarray(md) > 8.0)
    rank = 7
    top = engine.top_k_init(rank)
    outs = {}
    for impl in ("ref", "pallas"):
        d1, t1 = engine.eim_filter_block(x, c, md, h, top, rank=rank,
                                         impl=impl, chunk=chunk)
        outs[impl] = (np.asarray(d1), np.asarray(t1))
    np.testing.assert_array_equal(outs["ref"][0], outs["pallas"][0])
    np.testing.assert_array_equal(outs["ref"][1], outs["pallas"][1])


@pytest.mark.parametrize("rows", [256, 999])
def test_mrg_eim_host_stream_pallas_bitwise(rows):
    import jax
    from repro.core import HostStreamExecutor, eim, mrg
    from repro.data import HostSource
    rng = np.random.default_rng(27)
    x = rng.normal(size=(3000, 4)).astype(np.float32)
    ex = HostStreamExecutor(block_rows=rows)
    m0 = mrg(HostSource(x), 6, executor=ex, impl="ref")
    m1 = mrg(HostSource(x), 6, executor=ex, impl="pallas")
    np.testing.assert_array_equal(np.asarray(m0.centers),
                                  np.asarray(m1.centers))
    assert float(m0.radius2) == float(m1.radius2)
    e0 = eim(HostSource(x), 5, jax.random.PRNGKey(0), executor=ex,
             impl="ref")
    e1 = eim(HostSource(x), 5, jax.random.PRNGKey(0), executor=ex,
             impl="pallas")
    np.testing.assert_array_equal(np.asarray(e0.centers),
                                  np.asarray(e1.centers))
    assert float(e0.radius2) == float(e1.radius2)


def test_sim_executor_filter_round_pallas_bitwise():
    import jax
    from repro.core import SimExecutor, eim
    rng = np.random.default_rng(28)
    x = rng.normal(size=(2000, 4)).astype(np.float32)
    ex = SimExecutor(m=7)
    e0 = eim(jnp.asarray(x), 5, jax.random.PRNGKey(1), executor=ex,
             impl="ref")
    e1 = eim(jnp.asarray(x), 5, jax.random.PRNGKey(1), executor=ex,
             impl="pallas")
    np.testing.assert_array_equal(np.asarray(e0.centers),
                                  np.asarray(e1.centers))
    assert float(e0.radius2) == float(e1.radius2)


def test_fused_stream_one_compilation_across_ragged_tails(monkeypatch):
    # One fixed padded shape — and thus one compilation — must serve every
    # block of a stream, ragged tail included (the R004 contract).
    from repro.data import HostSource
    from repro.kernels import fused_stream
    x, c, _ = _data(seed=29)             # n=1000, blocks of 256 -> tail 232
    real = fused_stream.fused_filter_blocks
    if hasattr(real, "clear_cache"):
        real.clear_cache()
    seen = []

    def spy(xp, cp, dp, hp, **kw):
        seen.append((xp.shape, dp.shape, hp.shape,
                     kw["rank"], kw["bn"], kw["interpret"]))
        return real(xp, cp, dp, hp, **kw)

    monkeypatch.setattr(engine.fused_stream, "fused_filter_blocks", spy)
    ops.fold_min_d2(HostSource(np.asarray(x)), c, impl="pallas",
                    block_rows=256)
    assert len(seen) == 4                 # 256+256+256+232
    assert len(set(seen)) == 1            # ...all padded to ONE signature
    if hasattr(real, "_cache_size"):
        assert real._cache_size() == 1    # one XLA compilation total


def test_resolve_chunk_sublane_budget_honesty():
    # Budget-derived chunks are floored to the sublane multiple the kernel
    # will actually run, so the stated budget is never exceeded.
    n, m, d = 10 ** 6, 100, 32
    budget = 256 * 1024
    chunk = engine.resolve_chunk(n, m, d, memory_budget=budget, sublane=8)
    assert chunk % 8 == 0
    assert 4 * chunk * (m + d) + 4 * m * d <= budget
    # ...and flooring never loses more than one sublane block of rows.
    assert 4 * (chunk + 8) * (m + d) + 4 * m * d > budget
    # A budget that can't hold one sublane block raises rather than
    # silently overshooting.
    tiny = 4 * m * d + 4 * 7 * (m + d)    # covers 7 rows < one block
    with pytest.raises(ValueError, match="sublane"):
        engine.resolve_chunk(n, m, d, memory_budget=tiny, sublane=8)
    # Explicit chunk is a shape request: returned unrounded.
    assert engine.resolve_chunk(n, m, d, chunk=13, sublane=8) == 13


def test_resolve_impl_feature_detection(monkeypatch):
    # On the CPU CI backend there is no native lowering: auto falls back
    # to ref, and forcing pallas engages interpret mode.
    assert not engine._pallas_native()    # CPU test environment
    assert engine._resolve("ref") == (False, False)
    assert engine._resolve("auto") == (False, False)
    assert engine._resolve("pallas") == (True, True)
    with pytest.raises(ValueError):
        engine._resolve("mosaic")
    # With a native lowering available, auto uses Pallas natively.
    monkeypatch.setattr(engine, "_pallas_native", lambda: True)
    assert engine._resolve("auto") == (True, False)
    assert engine._resolve("pallas") == (True, False)
