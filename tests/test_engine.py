"""Chunked-engine parity: streaming row-blocks must not change results.

Contract (kernels/engine.py): for every op and every chunk size — including
chunk = 1, chunk that doesn't divide n, and chunk > n — the chunked result
equals the un-chunked reference. On the ref path elementwise outputs are
bitwise-equal (identical per-row arithmetic, only the iteration structure
changes); the Pallas path is validated to kernel tolerance.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gonzalez
from repro.core.mrg import mrg_sim
from repro.kernels import engine, ops, ref

CHUNKS = [1, 3, 8, 100, 512, 999, 1000, 4096]   # vs n=1000: tiny, odd,
                                                # divisible, ==n, >n


def _data(n=1000, m=13, d=7, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    md = jnp.asarray(rng.uniform(0.5, 20, size=(n,)).astype(np.float32))
    return x, c, md


@pytest.mark.parametrize("chunk", CHUNKS)
def test_assign_nearest_chunk_parity_ref(chunk):
    x, c, _ = _data()
    i0, d0 = ref.assign_nearest(x, c)
    i1, d1 = ops.assign_nearest(x, c, impl="ref", chunk=chunk)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("chunk", CHUNKS)
def test_fused_min_argmax_chunk_parity_ref(chunk):
    x, c, md = _data(seed=1)
    nm0, fv0, fi0 = ref.fused_min_argmax(x, c[0], md)
    nm1, fv1, fi1 = ops.fused_min_argmax(x, c[0], md, impl="ref",
                                         chunk=chunk)
    np.testing.assert_array_equal(np.asarray(nm0), np.asarray(nm1))
    assert int(fi0) == int(fi1)
    assert float(fv0) == float(fv1)


@pytest.mark.parametrize("chunk", CHUNKS)
def test_pairwise_dist2_chunk_parity_ref(chunk):
    x, c, _ = _data(seed=2)
    p0 = ref.pairwise_dist2(x, c)
    p1 = ops.pairwise_dist2(x, c, impl="ref", chunk=chunk)
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))


def test_fused_min_argmax_cross_chunk_tie_breaks_to_first():
    # Two exactly-equal global maxima in different chunks: the chunked
    # reduction must return the first index, like jnp.argmax.
    x = jnp.zeros((8, 2), jnp.float32)
    md = jnp.asarray([1.0, 5.0, 2.0, 3.0, 1.0, 5.0, 0.5, 0.5], jnp.float32)
    c = jnp.asarray([100.0, 100.0], jnp.float32)  # far: min stays md
    _, _, fi = ops.fused_min_argmax(x, c, md, impl="ref", chunk=2)
    assert int(fi) == 1


@pytest.mark.parametrize("chunk", [1, 7, 64, 2000])
def test_assign_nearest_chunk_parity_pallas(chunk):
    x, c, _ = _data(n=257, m=9, seed=3)
    i0, d0 = ref.assign_nearest(x, c)
    i1, d1 = ops.assign_nearest(x, c, impl="pallas", chunk=chunk, bn=64,
                                bm=8)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=1e-4,
                               atol=1e-4)
    # ties can legitimately differ; compare indices where nearest is unique
    d2 = np.asarray(ref.pairwise_dist2(x, c))
    part = np.partition(d2, 1, axis=1)
    unique = part[:, 1] - part[:, 0] > 1e-5
    assert (np.asarray(i0)[unique] == np.asarray(i1)[unique]).all()


@pytest.mark.parametrize("chunk", CHUNKS)
def test_argmin_dist2_over_rows_chunk_parity_ref(chunk):
    x, c, _ = _data(seed=6)
    i0, _ = ref.assign_nearest(c, x)   # unchunked oracle: (m,) over n rows
    i1 = ops.argmin_dist2_over_rows(x, c, impl="ref", chunk=chunk)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_eim_chunk_invariant():
    import jax
    from repro.core import eim
    x, _, _ = _data(n=2000, seed=7)
    r0 = eim(x, 5, jax.random.PRNGKey(0), impl="ref")
    r1 = eim(x, 5, jax.random.PRNGKey(0), impl="ref", chunk=123)
    np.testing.assert_array_equal(np.asarray(r0.centers),
                                  np.asarray(r1.centers))
    assert float(r0.radius2) == float(r1.radius2)


def test_coreset_chunk_invariant():
    from repro.core import select_coreset
    x, _, _ = _data(n=500, d=16, seed=8)
    c0 = select_coreset(x, 8, impl="ref")
    c1 = select_coreset(x, 8, impl="ref", chunk=77)
    np.testing.assert_array_equal(np.asarray(c0.indices),
                                  np.asarray(c1.indices))
    np.testing.assert_array_equal(np.asarray(c0.weights),
                                  np.asarray(c1.weights))


def test_memory_budget_resolves_and_matches():
    x, c, _ = _data()
    n, d = x.shape
    m = c.shape[0]
    budget = 64 * 1024
    chunk = engine.resolve_chunk(n, m, d, memory_budget=budget)
    assert 1 <= chunk < n                       # budget actually forces
    assert 4 * chunk * (m + d) + 4 * m * d <= budget  # streaming model holds
    i0, d0 = ref.assign_nearest(x, c)
    i1, d1 = ops.assign_nearest(x, c, impl="ref", memory_budget=budget)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_memory_budget_too_small_raises():
    with pytest.raises(ValueError):
        engine.resolve_chunk(1000, 1000, 128, memory_budget=1024)


def test_chunk_invalid_raises():
    with pytest.raises(ValueError):
        engine.resolve_chunk(10, 3, 2, chunk=0)


@pytest.mark.parametrize("chunk", [1, 37, 999, 1000, 4096])
def test_gonzalez_radius_invariant_under_chunk(chunk):
    x, _, _ = _data(seed=4)
    g0 = gonzalez(x, 8, impl="ref")
    g1 = gonzalez(x, 8, impl="ref", chunk=chunk)
    np.testing.assert_array_equal(np.asarray(g0.indices),
                                  np.asarray(g1.indices))
    assert float(g0.radius2) == float(g1.radius2)


def test_mrg_sim_chunk_invariant():
    x, _, _ = _data(seed=5)
    r0 = mrg_sim(x, 6, m=10, impl="ref")
    r1 = mrg_sim(x, 6, m=10, impl="ref", chunk=33)
    np.testing.assert_array_equal(np.asarray(r0.centers),
                                  np.asarray(r1.centers))
    assert float(r0.radius2) == float(r1.radius2)


# ---------------------------------------------------------------------------
# source folds (engine.py): block-streamed ops over a PointSource
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows", [1, 77, 256, 1000, 4096])
def test_fold_min_d2_matches_assign_max(rows):
    from repro.data import HostSource
    x, c, _ = _data(seed=9)
    _, d2 = ref.assign_nearest(x, c)
    got = ops.fold_min_d2(HostSource(np.asarray(x)), c, impl="ref",
                          block_rows=rows)
    assert float(jnp.max(d2)) == float(got)


@pytest.mark.parametrize("rows", [1, 77, 256, 1000, 4096])
def test_assign_nearest_source_concat_parity(rows):
    from repro.data import HostSource
    x, c, _ = _data(seed=10)
    i0, d0 = ref.assign_nearest(x, c)
    parts = list(ops.assign_nearest_source(HostSource(np.asarray(x)), c,
                                           impl="ref", block_rows=rows))
    i1 = np.concatenate([np.asarray(i) for i, _ in parts])
    d1 = np.concatenate([np.asarray(d) for _, d in parts])
    np.testing.assert_array_equal(np.asarray(i0), i1)
    np.testing.assert_array_equal(np.asarray(d0), d1)


@pytest.mark.parametrize("rows", [1, 77, 256, 1000, 4096])
def test_argmin_dist2_over_source_parity(rows):
    from repro.data import HostSource
    x, c, _ = _data(seed=11)
    i0, _ = ref.assign_nearest(c, x)   # unchunked oracle: (m,) over n rows
    i1 = ops.argmin_dist2_over_source(HostSource(np.asarray(x)), c,
                                      impl="ref", block_rows=rows)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


def test_resolve_block_rows_model():
    # explicit rows win, clipped to n
    assert engine.resolve_block_rows(100, 8, block_rows=7) == 7
    assert engine.resolve_block_rows(100, 8, block_rows=500) == 100
    # budget model: (1+prefetch)·4·rows·(d+1) <= budget (the consumed block
    # plus the prefetch ring; default prefetch=2 => 3 resident blocks)
    rows = engine.resolve_block_rows(10 ** 9, 7, memory_budget=1 << 20)
    assert 12 * rows * 8 <= 1 << 20 < 12 * (rows + 1) * 8
    # prefetch=1 recovers the PR-2 double-buffer model
    rows1 = engine.resolve_block_rows(10 ** 9, 7, memory_budget=1 << 20,
                                      prefetch=1)
    assert 8 * rows1 * 8 <= 1 << 20 < 8 * (rows1 + 1) * 8
    with pytest.raises(ValueError):
        engine.resolve_block_rows(100, 8, block_rows=0)
    with pytest.raises(ValueError):
        engine.resolve_block_rows(100, 1024, memory_budget=64)
    with pytest.raises(ValueError):
        engine.resolve_block_rows(100, 8, memory_budget=1 << 20, prefetch=0)
