"""Sharding spec rules: divisibility fitting, path matching, cache specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P


@pytest.fixture(scope="module")
def mesh():
    # 1-device "mesh" with the production axis names: spec resolution is
    # pure metadata, so a single device suffices for unit tests.
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_param_rules_logical():
    # rule matching is mesh-independent — test the pure logical mapping
    from repro.sharding.specs import _param_logical
    assert _param_logical("embed", (1024, 64), False) == ("tp", "dp")
    assert _param_logical("layers/attn/wq", (4, 64, 128), True) == \
        (None, "dp", "tp")
    assert _param_logical("layers/attn/wo", (4, 128, 64), True) == \
        (None, "tp", "dp")
    assert _param_logical("opt/mu/layers/mlp/w_down", (4, 256, 64), True) \
        == (None, "tp", "dp")
    assert _param_logical("layers/moe/w_gate", (4, 8, 64, 256), True) == \
        (None, "tp", "dp", None)
    assert _param_logical("final_norm/scale", (64,), False) == (None,)


def test_divisibility_fitting(mesh):
    from repro.sharding import params_pspecs
    # vocab 50281 is indivisible by any axis > 1 — must drop sharding
    shapes = {"embed": jax.ShapeDtypeStruct((50281, 64), jnp.bfloat16)}
    specs = params_pspecs(shapes, mesh)
    # on the 1x1 test mesh sizes are 1 ⇒ everything drops to None
    assert specs["embed"] == P(None, None)


def test_batch_small_batch_not_sharded(mesh):
    from repro.sharding import batch_pspecs
    b = {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)}
    specs = batch_pspecs(b, mesh)
    assert specs["tokens"] == P(None, None)


def test_cache_specs_sequence_parallel(mesh):
    from repro.sharding import cache_pspecs
    c = {"k": jax.ShapeDtypeStruct((24, 128, 32768, 2, 64), jnp.bfloat16),
         "pos": jax.ShapeDtypeStruct((128,), jnp.int32),
         "state": jax.ShapeDtypeStruct((48, 1, 32, 128, 64), jnp.float32)}
    specs = cache_pspecs(c, mesh)
    # on 1x1 mesh all resolve to None but structure must be preserved
    assert specs["k"] == P(None, None, None, None, None)
    assert specs["pos"] == P(None)


def test_auto_spec_prefers_largest_divisible():
    from repro.sharding import auto_spec
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    got = auto_spec((61, 24, 448), mesh)
    assert len(got) == 3


def test_make_mesh_explicit_devices():
    # launch.mesh.make_mesh must honor an explicit device list (the
    # multi-process contract: meshes are built over the *global* device
    # set, which under jax.distributed is a strict superset of what
    # jax.local_devices() would give a per-process default).
    from repro.launch.mesh import make_cluster_mesh, make_mesh
    devs = jax.devices()
    m = make_mesh((1,), ("data",), devices=devs[:1])
    assert list(m.devices.flat) == devs[:1]
    # default is the full jax.devices() set, not a local subset
    m2 = make_mesh((len(devs),), ("data",))
    assert list(m2.devices.flat) == devs
    with pytest.raises(ValueError, match="need 2 devices"):
        make_mesh((2,), ("data",), devices=devs[:1])
    # single-process degenerate cluster mesh == make_mesh over all devices
    cm = make_cluster_mesh()
    assert list(cm.devices.flat) == devs
    assert cm.axis_names == ("data",)
    with pytest.raises(ValueError, match="single sharding axis"):
        make_cluster_mesh(axes=("data", "model"))
