"""Docs stay runnable: doctests on the public surface, README snippets,
and the quickstart example.

Three rot-prevention contracts (the docs satellite of the sharded-source
PR):

  * every doctest in the public API modules (``mrg`` / ``eim`` /
    ``gonzalez`` / ``select_coreset`` / the sources) executes and matches;
  * every ``python`` code block in README.md executes top-to-bottom in one
    shared namespace (the quickstart snippets build on each other);
  * ``examples/quickstart.py`` runs end to end (small ``--n``) — its
    internal bitwise assertions double as a parity check.
"""
import doctest
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOCTEST_MODULES = [
    "repro.core.mrg",
    "repro.core.gonzalez",
    "repro.core.eim",
    "repro.core.coreset",
    "repro.core.outliers",
    "repro.data.source",
]


@pytest.mark.parametrize("modname", DOCTEST_MODULES)
def test_public_api_doctests(modname):
    mod = __import__(modname, fromlist=["_"])
    result = doctest.testmod(mod, verbose=False)
    assert result.attempted > 0, f"{modname} lost its doctests"
    assert result.failed == 0, f"{modname}: {result.failed} doctest(s) failed"


def _readme_blocks():
    with open(os.path.join(REPO, "README.md")) as f:
        text = f.read()
    return re.findall(r"```python\n(.*?)```", text, flags=re.S)


def test_readme_python_blocks_execute():
    blocks = _readme_blocks()
    assert len(blocks) >= 3, "README lost its quickstart snippets"
    ns: dict = {}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"README.md[block {i}]", "exec"), ns)
        except Exception as err:  # pragma: no cover - failure reporting
            raise AssertionError(
                f"README.md python block {i} failed: {err}\n{block}") from err


def test_quickstart_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "quickstart.py"),
         "--n", "20000"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("GON", "MRG", "EIM", "out-of-core", "sharded"):
        assert tag in out.stdout, f"quickstart output lost its {tag} row"


def test_coreset_curation_example_runs():
    """The curation example end to end (small --n): its internal
    assertions double as checks that curated ≤ random under the same
    streamed fold, weights are conserved, and the outlier pass excludes
    the planted contamination."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "examples", "coreset_curation.py"),
         "--n", "4000"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("curated", "random", "weighted coreset", "kz_center"):
        assert tag in out.stdout, f"curation output lost its {tag} row"
