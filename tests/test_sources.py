"""Out-of-core substrate: PointSources, executors, and the unified ``mrg``.

Contracts under test (data/source.py + core/executor.py):

  * every source reproduces the underlying rows exactly, for any blocking,
    including blocks that straddle on-disk shard boundaries;
  * ``mrg`` over ``ArraySource`` / ``HostSource`` / ``MemmapSource`` with
    the same machine blocking returns *bitwise identical* centers and
    radius to the in-memory ``mrg_sim`` (the ref path is deterministic and
    the executors don't change any per-row arithmetic);
  * ``HostStreamExecutor``'s realized round count equals the paper's
    ``plan_rounds`` recurrence (§3.3 inequality (1)) for matching
    (machines, capacity);
  * the streamed algorithm layer (gonzalez / covering_radius /
    select_coreset / stream_update) is exact vs the in-memory layer.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HostStreamExecutor, SimExecutor, covering_radius,
                        eim, gonzalez, mrg, mrg_sim, plan_rounds,
                        select_coreset, stream_init, stream_result,
                        stream_update)
from repro.data import (ArraySource, HostSource, IndexedSource, MemmapSource,
                        as_source, synthetic_source, unif)


def _pts(n=640, d=5, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# sources reproduce their rows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows", [1, 77, 128, 640, 1000])
def test_host_and_array_sources_roundtrip(rows):
    x = _pts()
    for src in (ArraySource(x), HostSource(x)):
        got = np.concatenate([np.asarray(b) for b in src.blocks(rows)])
        np.testing.assert_array_equal(got, x)
        assert src.n == x.shape[0] and src.d == x.shape[1]


@pytest.mark.parametrize("shard_rows,block_rows", [(200, 77), (100, 256),
                                                   (640, 640), (7, 64)])
def test_memmap_source_blocks_cross_shard_boundaries(tmp_path, shard_rows,
                                                     block_rows):
    x = _pts()
    src = MemmapSource.save_shards(x, tmp_path, rows_per_shard=shard_rows)
    got = np.concatenate([np.asarray(b) for b in src.blocks(block_rows)])
    np.testing.assert_array_equal(got, x)
    np.testing.assert_array_equal(np.asarray(src.materialize()), x)


def test_synthetic_unif_bitwise_matches_generator():
    # the Philox counter is advanced to each block's stream offset, so any
    # blocking reproduces the monolithic pointsets.unif call exactly
    full = unif(1000, 3, seed=42)
    src = synthetic_source("unif", 1000, d=3, seed=42)
    for rows in (64, 250, 1000):
        got = np.concatenate([np.asarray(b) for b in src.blocks(rows)])
        np.testing.assert_array_equal(got, full)


def test_synthetic_gau_restartable_and_shaped():
    src = synthetic_source("gau", 500, d=2, seed=7, k_prime=5)
    a = np.concatenate([np.asarray(b) for b in src.blocks(100)])
    b = np.concatenate([np.asarray(b) for b in src.blocks(100)])
    np.testing.assert_array_equal(a, b)   # streams restart deterministically
    assert a.shape == (500, 2)


def test_source_row_random_access(tmp_path):
    x = _pts()
    srcs = [ArraySource(x), HostSource(x),
            MemmapSource.save_shards(x, tmp_path, rows_per_shard=100),
            synthetic_source("unif", 1000, d=3, seed=42)]
    full = unif(1000, 3, seed=42)
    for src, ref in zip(srcs, [x, x, x, full]):
        for idx in (0, 1, 99, 100, ref.shape[0] - 1):
            np.testing.assert_array_equal(np.asarray(src.row(idx)), ref[idx])
    with pytest.raises(IndexError):
        from repro.core.gonzalez import _source_row
        _source_row(HostSource(x), x.shape[0], 100)


def test_source_take_random_access_gather(tmp_path):
    x = _pts()
    idx = np.array([5, 0, 639, 100, 101, 102, 7])   # unsorted, with a run
    srcs = [ArraySource(x), HostSource(x),
            MemmapSource.save_shards(x, tmp_path, rows_per_shard=100)]
    for src in srcs:
        np.testing.assert_array_equal(src.take(idx), x[idx])
        np.testing.assert_array_equal(src.take([]),
                                      np.zeros((0, x.shape[1]), np.float32))
    # synthetic take regenerates the containing runs bitwise
    full = unif(1000, 3, seed=42)
    syn = synthetic_source("unif", 1000, d=3, seed=42)
    np.testing.assert_array_equal(syn.take(idx), full[idx])
    for src in srcs + [syn]:
        with pytest.raises(IndexError):
            src.take([src.n])
        with pytest.raises(IndexError):
            src.take([-1])


@pytest.mark.parametrize("prefetch", [1, 2, 4])
def test_blocks_prefetch_ring_reproduces_rows(tmp_path, prefetch):
    # the ring is a transfer-scheduling detail: any depth yields the same
    # rows in the same order (prefetch=1 is the PR-2 double buffer)
    x = _pts()
    for src in (HostSource(x),
                MemmapSource.save_shards(x, tmp_path, rows_per_shard=150),
                synthetic_source("unif", 640, d=5, seed=3)):
        ref = np.concatenate([np.asarray(b) for b in src.blocks(77)])
        got = np.concatenate(
            [np.asarray(b) for b in src.blocks(77, prefetch=prefetch)])
        np.testing.assert_array_equal(got, ref)


def test_prefetch_validation():
    x = _pts(n=32, d=2)
    with pytest.raises(ValueError):
        list(HostSource(x).blocks(8, prefetch=0))
    with pytest.raises(ValueError):
        HostStreamExecutor(prefetch=0)


def test_as_source_coercion():
    x = _pts()
    assert isinstance(as_source(x), HostSource)
    assert isinstance(as_source(jnp.asarray(x)), ArraySource)
    src = HostSource(x)
    assert as_source(src) is src


# ---------------------------------------------------------------------------
# IndexedSource: sorted global-row views (the compacted-R substrate)
# ---------------------------------------------------------------------------

def _view_parents(tmp_path):
    x = _pts()
    full = unif(640, 5, seed=21)
    return [(HostSource(x), x),
            (ArraySource(x), x),
            (MemmapSource.save_shards(x, tmp_path, rows_per_shard=100), x),
            (synthetic_source("unif", 640, d=5, seed=21), full)]


@pytest.mark.parametrize("block_rows", [1, 7, 64, 1000])
def test_indexed_source_blocks_match_fancy_index(tmp_path, block_rows):
    idx = np.unique(np.random.default_rng(5).choice(640, 200, replace=False))
    for parent, ref in _view_parents(tmp_path):
        v = IndexedSource(parent, idx)
        assert v.n == idx.size and v.d == 5
        got = np.concatenate([np.asarray(b) for b in v.blocks(block_rows)])
        np.testing.assert_array_equal(got, ref[idx])
        np.testing.assert_array_equal(np.asarray(v.materialize()), ref[idx])


def test_indexed_source_row_and_take_compose_indices(tmp_path):
    idx = np.array([0, 5, 6, 7, 100, 639])
    for parent, ref in _view_parents(tmp_path):
        v = IndexedSource(parent, idx)
        for j in range(idx.size):
            np.testing.assert_array_equal(np.asarray(v.row(j)), ref[idx[j]])
        np.testing.assert_array_equal(v.take([5, 0, 2]),
                                      ref[idx][[5, 0, 2]])
        with pytest.raises(IndexError):
            v.take([idx.size])
        with pytest.raises(IndexError):
            v.row(idx.size)


def test_indexed_source_rejects_duplicates_unsorted_and_oob():
    src = HostSource(_pts())
    with pytest.raises(ValueError, match="strictly increasing"):
        IndexedSource(src, [1, 1, 2])           # duplicate
    with pytest.raises(ValueError, match="strictly increasing"):
        IndexedSource(src, [5, 3])              # unsorted
    with pytest.raises(IndexError, match="out of range"):
        IndexedSource(src, [0, 640])            # past n
    with pytest.raises(IndexError, match="out of range"):
        IndexedSource(src, [-1, 0])


def test_indexed_source_nested_views_compose():
    x = _pts()
    src = HostSource(x)
    outer = IndexedSource(src, np.arange(0, 640, 2))     # evens
    inner = IndexedSource(outer, np.array([0, 3, 10, 319]))
    # the nested view re-points at the root parent with composed indices
    assert inner.parent is src
    np.testing.assert_array_equal(inner.indices, np.array([0, 6, 20, 638]))
    np.testing.assert_array_equal(np.asarray(inner.materialize()),
                                  x[[0, 6, 20, 638]])
    # empty view is legal (a fully-filtered relation)
    empty = IndexedSource(src, np.zeros((0,), np.int64))
    assert empty.n == 0
    assert list(empty.blocks(8)) == []


def test_memmap_many_shards_slice_visits_only_overlaps(tmp_path):
    # 64+ shards: block streams and materialize must stay bitwise while
    # _slice locates overlapping shards by searchsorted instead of
    # scanning every shard per block
    x = _pts(n=1280, d=3, seed=17)
    src = MemmapSource.save_shards(x, tmp_path, rows_per_shard=20)
    assert src.num_shards == 64
    for rows in (1, 19, 20, 33, 256, 1280):
        got = np.concatenate([np.asarray(b) for b in src.blocks(rows)])
        np.testing.assert_array_equal(got, x)
    np.testing.assert_array_equal(np.asarray(src.materialize()), x)
    np.testing.assert_array_equal(src._slice(19, 21), x[19:21])
    np.testing.assert_array_equal(src._slice(0, 1), x[0:1])
    np.testing.assert_array_equal(src._slice(1279, 1280), x[1279:1280])
    assert src._slice(7, 7).shape == (0, 3)


# ---------------------------------------------------------------------------
# mrg parity across sources/executors (the ISSUE's acceptance bar)
# ---------------------------------------------------------------------------

def test_mrg_array_source_equals_mrg_sim():
    x = _pts()
    r_sim = mrg_sim(jnp.asarray(x), 7, m=8, impl="ref")
    r_arr = mrg(ArraySource(x), 7, m=8, impl="ref")
    np.testing.assert_array_equal(np.asarray(r_sim.centers),
                                  np.asarray(r_arr.centers))
    assert float(r_sim.radius2) == float(r_arr.radius2)
    assert r_sim.rounds == r_arr.rounds == 2


def test_mrg_host_source_bitwise_equals_mrg_sim():
    # same blocking: m=8 machines of 80 rows == super-shards of 80 rows
    x = _pts()
    r_sim = mrg_sim(jnp.asarray(x), 7, m=8, impl="ref")
    r_host = mrg(HostSource(x), 7, impl="ref",
                 executor=HostStreamExecutor(block_rows=80))
    np.testing.assert_array_equal(np.asarray(r_sim.centers),
                                  np.asarray(r_host.centers))
    assert float(r_sim.radius2) == float(r_host.radius2)
    assert r_sim.rounds == r_host.rounds


def test_mrg_memmap_source_bitwise_equals_mrg_sim(tmp_path):
    # shard size deliberately != machine blocking: the source's global-row
    # blocks hide the disk layout
    x = _pts()
    src = MemmapSource.save_shards(x, tmp_path, rows_per_shard=200)
    r_sim = mrg_sim(jnp.asarray(x), 7, m=8, impl="ref")
    r_mm = mrg(src, 7, impl="ref",
               executor=HostStreamExecutor(block_rows=80))
    np.testing.assert_array_equal(np.asarray(r_sim.centers),
                                  np.asarray(r_mm.centers))
    assert float(r_sim.radius2) == float(r_mm.radius2)


def test_mrg_multiround_parity_and_memory_budget():
    # capacity forces Lemma-3 extra rounds; both substrates reduce the same
    # union on the same re-blocking
    x = _pts()
    r_sim = mrg_sim(jnp.asarray(x), 7, m=8, capacity=20, impl="ref")
    r_host = mrg(HostSource(x), 7, capacity=20, impl="ref",
                 executor=HostStreamExecutor(block_rows=80))
    assert r_sim.rounds == r_host.rounds > 2
    np.testing.assert_array_equal(np.asarray(r_sim.centers),
                                  np.asarray(r_host.centers))
    assert float(r_sim.radius2) == float(r_host.radius2)
    # a byte budget resolves to the same 80-row super-shards:
    # (1+prefetch)·4·rows·(d+1) <= budget with the default prefetch=2 ring
    # =>  rows = budget // 72
    r_bud = mrg(HostSource(x), 7, capacity=20, impl="ref",
                executor=HostStreamExecutor(memory_budget=80 * 12 * 6))
    np.testing.assert_array_equal(np.asarray(r_host.centers),
                                  np.asarray(r_bud.centers))


def test_mrg_default_executor_picks_substrate():
    x = _pts(n=200, d=3, seed=3)
    r_dev = mrg(jnp.asarray(x), 5, m=4, impl="ref")   # -> SimExecutor
    r_str = mrg(HostSource(x), 5, impl="ref",
                executor=HostStreamExecutor(block_rows=50))
    np.testing.assert_array_equal(np.asarray(r_dev.centers),
                                  np.asarray(r_str.centers))
    # default for a host source is HostStreamExecutor (65536-row shards:
    # one block here, so rounds collapse to the 2-level classic form)
    assert mrg(HostSource(x), 5, impl="ref").rounds == 2


@pytest.mark.parametrize("n,rows,k,capacity", [
    (640, 80, 7, 80),      # k*m = 56 <= 80: classic 2 rounds
    (640, 80, 7, 20),      # 56 > 20: extra levels
    (3000, 100, 8, 64),    # 240 > 64: deeper recursion
    (1000, 10, 2, 5),      # k/c = 0.4: many levels
    (512, 512, 4, 512),    # single machine
])
def test_plan_rounds_matches_host_stream_executor(n, rows, k, capacity):
    """§3.3 recurrence == realized rounds on the out-of-core substrate."""
    m = -(-n // rows)
    expected = plan_rounds(n, m, k, capacity)
    x = _pts(n=n, d=3, seed=n + k)
    got = mrg(HostSource(x), k, capacity=capacity, impl="ref",
              executor=HostStreamExecutor(block_rows=rows)).rounds
    assert got == expected


# ---------------------------------------------------------------------------
# streamed algorithm layer parity
# ---------------------------------------------------------------------------

def test_gonzalez_streamed_bitwise():
    x = _pts()
    g0 = gonzalez(jnp.asarray(x), 7, impl="ref")
    g1 = gonzalez(HostSource(x), 7, impl="ref", block_rows=100)
    np.testing.assert_array_equal(np.asarray(g0.centers),
                                  np.asarray(g1.centers))
    np.testing.assert_array_equal(np.asarray(g0.indices),
                                  np.asarray(g1.indices))
    assert float(g0.radius2) == float(g1.radius2)
    np.testing.assert_array_equal(np.asarray(g0.min_d2),
                                  np.asarray(g1.min_d2))


def test_gonzalez_streamed_rejects_mask():
    x = _pts(n=64, d=2)
    with pytest.raises(ValueError):
        gonzalez(HostSource(x), 3, mask=jnp.ones(64, bool))


def test_covering_radius_streamed_bitwise():
    x = _pts()
    c = gonzalez(jnp.asarray(x), 5, impl="ref").centers
    r0 = float(covering_radius(jnp.asarray(x), c, impl="ref"))
    r1 = float(covering_radius(HostSource(x), c, impl="ref", block_rows=90))
    assert r0 == r1


def test_select_coreset_streamed_parity():
    x = _pts(n=500, d=16, seed=8)
    c0 = select_coreset(jnp.asarray(x), 8, impl="ref")
    c1 = select_coreset(HostSource(x), 8, impl="ref", block_rows=77)
    np.testing.assert_array_equal(np.asarray(c0.indices),
                                  np.asarray(c1.indices))
    np.testing.assert_array_equal(np.asarray(c0.weights),
                                  np.asarray(c1.weights))
    assert float(c0.radius2) == float(c1.radius2)


def test_select_coreset_executor_runs_mrg():
    x = _pts(n=400, d=4, seed=9)
    cs = select_coreset(HostSource(x), 6, impl="ref",
                        executor=HostStreamExecutor(block_rows=100))
    assert cs.centers.shape == (6, 4)
    assert float(jnp.sum(cs.weights)) == 400.0
    # MRG (<=4-approx) vs GON (>=OPT): radius ratio bounded by 4
    g = gonzalez(jnp.asarray(x), 6, impl="ref")
    assert float(jnp.sqrt(cs.radius2)) <= \
        4.0 * float(jnp.sqrt(g.radius2)) + 1e-5


def test_stream_update_accepts_source():
    x = _pts(n=900, d=4, seed=10)
    s0 = stream_init(8, 4)
    for i in range(0, 900, 300):
        s0 = stream_update(s0, x[i:i + 300])
    s1 = stream_update(stream_init(8, 4), HostSource(x), block_rows=300)
    c0, r0 = stream_result(s0)
    c1, r1 = stream_result(s1)
    np.testing.assert_array_equal(c0, c1)
    assert r0 == r1


def test_eim_accepts_source():
    import jax
    x = _pts(n=2000, d=3, seed=11)
    r0 = eim(jnp.asarray(x), 5, jax.random.PRNGKey(0), impl="ref")
    r1 = eim(ArraySource(x), 5, jax.random.PRNGKey(0), impl="ref")
    r2 = eim(HostSource(x), 5, jax.random.PRNGKey(0), impl="ref")
    for r in (r1, r2):
        np.testing.assert_array_equal(np.asarray(r0.centers),
                                      np.asarray(r.centers))
        assert float(r0.radius2) == float(r.radius2)


# ---------------------------------------------------------------------------
# executor edge cases
# ---------------------------------------------------------------------------

def test_sim_executor_rejects_zero_machines():
    with pytest.raises(ValueError):
        SimExecutor(m=0)


def test_mesh_executor_rejects_capacity_on_fused_device_path():
    # The fused shard_map program's machine blocking is fixed by the mesh;
    # only the streamed sharded path (host-backed / ShardedSource inputs)
    # honors capacity=.
    from repro.core import MeshExecutor
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="capacity"):
        MeshExecutor(mesh).mrg(ArraySource(_pts(n=16, d=2)), 2, capacity=8)


def test_mesh_executor_streamed_path_honors_capacity():
    # A host-backed source on MeshExecutor runs the streamed sharded
    # rounds: capacity= triggers the shared Lemma-3 combine, exactly like
    # HostStreamExecutor with the same blocking.
    from repro.core import MeshExecutor
    from repro.launch.mesh import make_mesh
    x = _pts(n=512, d=3, seed=11)
    mesh = make_mesh((1,), ("data",))
    k, cap = 4, 16
    r_mesh = mrg(HostSource(x), k, capacity=cap,
                 executor=MeshExecutor(mesh, block_rows=32), impl="ref")
    r_host = mrg(HostSource(x), k, capacity=cap,
                 executor=HostStreamExecutor(block_rows=32), impl="ref")
    assert r_mesh.rounds == r_host.rounds > 2
    assert np.array_equal(np.asarray(r_mesh.centers),
                          np.asarray(r_host.centers))
    assert float(r_mesh.radius2) == float(r_host.radius2)


class _RecordingSource(HostSource):
    """HostSource that records every requested block size."""

    def __init__(self, x):
        super().__init__(x)
        self.requested = set()

    def blocks(self, block_rows):
        self.requested.add(block_rows)
        return super().blocks(block_rows)


def test_select_coreset_reverse_passes_inherit_executor_budget():
    # every pass — rounds, radius fold, and both reverse passes — must use
    # the executor's blocking, not the 65536-row default
    src = _RecordingSource(_pts(n=400, d=4, seed=13))
    select_coreset(src, 4, impl="ref",
                   executor=HostStreamExecutor(block_rows=50))
    assert src.requested == {50}


def test_mrg_infeasible_capacity_raises_instead_of_hanging():
    # regression: mrg(x, 8, capacity=4) used to loop forever in combine
    # (400 rows -> m2=100 -> 800 rows: the union grows every level)
    x = _pts(n=400, d=3, seed=20)
    with pytest.raises(ValueError, match="infeasible"):
        mrg(jnp.asarray(x), 8, capacity=4, impl="ref")
    with pytest.raises(ValueError, match="infeasible"):
        mrg(jnp.asarray(x), 8, capacity=8, impl="ref")   # capacity == k
    with pytest.raises(ValueError, match="infeasible"):
        mrg(HostSource(x), 8, capacity=4, impl="ref",
            executor=HostStreamExecutor(block_rows=50))
    # mrg(x, k, capacity=k//2) — the ISSUE's acceptance form
    with pytest.raises(ValueError, match="infeasible"):
        mrg(jnp.asarray(x), 8, capacity=4, impl="ref", m=8)


def test_combine_capacity_below_2k_warns_and_divergence_raises():
    # §3.3 requires 2k < c; k < capacity < 2k may stall on the ceil —
    # warn up front, and the divergence guard raises instead of spinning
    x = _pts(n=400, d=3, seed=21)
    with pytest.warns(RuntimeWarning, match="2k"):
        with pytest.raises(ValueError, match="diverged"):
            mrg(jnp.asarray(x), 8, m=8, capacity=12, impl="ref")
    # a feasible capacity >= 2k neither warns nor raises
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        r = mrg(jnp.asarray(x), 8, m=8, capacity=16, impl="ref")
    assert r.rounds >= 2


def test_combine_validates_directly():
    from repro.core.executor import Executor, check_combine_capacity
    with pytest.raises(ValueError, match="infeasible"):
        check_combine_capacity(8, 4)
    centers = jnp.asarray(_pts(n=64, d=2, seed=5))
    valid = jnp.ones(64, bool)
    with pytest.raises(ValueError, match="infeasible"):
        Executor().combine(centers, valid, 8, 4, impl="ref")


class _ShapeSpyFn:
    """BlockFn wrapper recording every block shape it is fed."""

    def __init__(self, fn):
        self.fn = fn
        self.shapes = []

    def __call__(self, pts, mask):
        self.shapes.append(tuple(pts.shape))
        return self.fn(pts, mask)


def test_run_blocks_pads_ragged_tail_to_one_shape():
    # jit-churn fix: the ragged final block is padded to `rows` with the
    # mask argument carrying validity, so the per-machine GON compiles
    # once per block shape instead of once per distinct tail size —
    # the spy shape set is the compile-count proxy
    from repro.core.executor import gon_block_fn
    x = _pts(n=400, d=3, seed=22)               # 400 = 150+150+100 tail
    spy = _ShapeSpyFn(gon_block_fn(4, "ref"))
    ex = HostStreamExecutor(block_rows=150)
    centers, valid = ex.run_blocks(spy, HostSource(x))
    assert set(spy.shapes) == {(150, 3)}
    assert centers.shape == (12, 3) and bool(valid.all())
    # padding is invisible in the result: the tail machine's centers are
    # the unpadded GON of the tail rows
    tail = gonzalez(jnp.asarray(x[300:]), 4, impl="ref").centers
    np.testing.assert_array_equal(np.asarray(centers[8:]), np.asarray(tail))


def test_executor_radius2_is_exact_squared_fold():
    # precision fix: radius2 returns max(min_d2) itself — not the lossy
    # f32 sqrt→square round-trip — identically on every executor path
    from repro.kernels import ops
    x = _pts(n=500, d=4, seed=23)
    c = gonzalez(jnp.asarray(x), 6, impl="ref").centers
    _, d2 = ops.assign_nearest(jnp.asarray(x), c, impl="ref")
    want = float(jnp.max(d2))
    assert float(SimExecutor(m=4).radius2(ArraySource(x), c,
                                          impl="ref")) == want
    assert float(HostStreamExecutor(block_rows=77).radius2(
        HostSource(x), c, impl="ref")) == want
    # and mrg surfaces that exact value
    r_mrg = mrg(HostSource(x), 6, impl="ref",
                executor=HostStreamExecutor(block_rows=77))
    _, d2m = ops.assign_nearest(jnp.asarray(x), r_mrg.centers, impl="ref")
    assert float(r_mrg.radius2) == float(jnp.max(d2m))


def test_host_stream_block_larger_than_n_is_one_machine():
    x = _pts(n=100, d=3, seed=12)
    r = mrg(HostSource(x), 4, impl="ref",
            executor=HostStreamExecutor(block_rows=10_000))
    # one super-shard == one simulated machine
    r1 = mrg_sim(jnp.asarray(x), 4, m=1, impl="ref")
    np.testing.assert_array_equal(np.asarray(r.centers),
                                  np.asarray(r1.centers))
    assert float(r.radius2) == float(r1.radius2)
    assert r.rounds == r1.rounds == 2


# ---------------------------------------------------------------------------
# sharded sources — the paper's "input already partitioned across machines"
# ---------------------------------------------------------------------------

def _sharded_imports():
    from repro.data import ShardedSource, SliceSource, shard_source
    return ShardedSource, SliceSource, shard_source


@pytest.mark.parametrize("rows", [1, 13, 64, 640])
def test_sharded_source_blocks_roundtrip(rows):
    ShardedSource, _, shard_source = _sharded_imports()
    x = _pts(n=103, d=3, seed=5)
    for sh in (shard_source(HostSource(x), 4),
               ShardedSource.from_per_host_shards(
                   [HostSource(x[:40]), HostSource(x[40:63]),
                    HostSource(x[63:])])):
        assert sh.n == 103 and sh.d == 3
        got = np.concatenate([np.asarray(b) for b in sh.blocks(rows)])
        np.testing.assert_array_equal(got, x)
        got_h = np.concatenate(list(sh.host_blocks(rows)))
        np.testing.assert_array_equal(got_h, x)


def test_sharded_source_take_row_materialize_across_shards():
    ShardedSource, _, shard_source = _sharded_imports()
    x = _pts(n=90, d=2, seed=6)
    sh = shard_source(HostSource(x), 3)
    idx = np.asarray([0, 29, 30, 59, 60, 89])  # shard-boundary straddlers
    np.testing.assert_array_equal(sh.take(idx), x[idx])
    for i in (0, 30, 89):
        np.testing.assert_array_equal(sh.row(i), x[i])
    np.testing.assert_array_equal(np.asarray(sh.materialize()), x)
    np.testing.assert_array_equal(sh.offsets, [0, 30, 60, 90])
    assert sh.max_shard_rows == 30


def test_shard_source_uses_sim_machine_blocking():
    # per = ceil(n/S), machine i holds [i*per, min((i+1)*per, n)) — the
    # SimExecutor blocking (what makes sharded runs bitwise comparable)
    _, SliceSource, shard_source = _sharded_imports()
    sh = shard_source(HostSource(_pts(n=10, d=2)), 4)
    assert [s.n for s in sh.shards] == [3, 3, 3, 1]
    assert all(isinstance(s, SliceSource) for s in sh.shards)
    # more shards than rows: trailing shards are empty but well-formed
    sh2 = shard_source(HostSource(_pts(n=3, d=2)), 5)
    assert [s.n for s in sh2.shards] == [1, 1, 1, 0, 0]
    assert sh2.n == 3


def test_shard_source_accepts_mesh_and_executor_and_passthrough():
    from repro.core import MeshExecutor
    from repro.launch.mesh import make_mesh
    ShardedSource, _, shard_source = _sharded_imports()
    x = _pts(n=64, d=2, seed=7)
    mesh = make_mesh((1,), ("data",))
    assert shard_source(HostSource(x), mesh).num_shards == 1
    assert shard_source(HostSource(x),
                        MeshExecutor(mesh)).num_shards == 1
    sh = shard_source(HostSource(x), 2)
    assert shard_source(sh, 2) is sh           # matching count passes through
    with pytest.raises(ValueError, match="already sharded"):
        shard_source(sh, 4)
    with pytest.raises(TypeError, match="shards"):
        shard_source(HostSource(x), "two")


def test_slice_source_composes_and_checks_bounds():
    _, SliceSource, _ = _sharded_imports()
    x = _pts(n=100, d=2, seed=8)
    src = HostSource(x)
    s = SliceSource(SliceSource(src, 10, 90), 5, 40)
    assert s.parent is src and s.start == 15 and s.stop == 50
    np.testing.assert_array_equal(np.asarray(s.materialize()), x[15:50])
    np.testing.assert_array_equal(s.take([0, 34]), x[[15, 49]])
    np.testing.assert_array_equal(s.row(0), x[15])
    with pytest.raises(ValueError, match="out of range"):
        SliceSource(src, 50, 101)
    with pytest.raises(IndexError):
        s.row(35)


def test_slice_source_synthetic_is_bitwise_the_monolithic_rows():
    # counter-based generators serve a slice by regeneration — bitwise the
    # same rows the monolithic stream would produce
    _, _, shard_source = _sharded_imports()
    syn = synthetic_source("unif", 1000, seed=3, d=2)
    mono = np.concatenate(list(syn.host_blocks(1000)))
    sh = shard_source(syn, 3)
    np.testing.assert_array_equal(
        np.concatenate(list(sh.host_blocks(64))), mono)


def test_sharded_source_validates_shards():
    ShardedSource, _, _ = _sharded_imports()
    with pytest.raises(ValueError, match="at least one"):
        ShardedSource([])
    with pytest.raises(ValueError, match="d="):
        ShardedSource([HostSource(_pts(8, d=2)), HostSource(_pts(8, d=3))])
    with pytest.raises(TypeError, match="PointSource"):
        ShardedSource([np.zeros((4, 2), np.float32)])


def test_sharded_source_streams_on_host_stream_executor():
    # A ShardedSource is a plain PointSource: the sequential executor folds
    # it shard after shard — bitwise the unsharded run when block_rows
    # divides the shard size (same machine blocks in the same order).
    _, _, shard_source = _sharded_imports()
    x = _pts(n=512, d=3, seed=9)
    sh = shard_source(HostSource(x), 4)
    r_sh = mrg(sh, 4, executor=HostStreamExecutor(block_rows=64), impl="ref")
    r_un = mrg(HostSource(x), 4, executor=HostStreamExecutor(block_rows=64),
               impl="ref")
    np.testing.assert_array_equal(np.asarray(r_sh.centers),
                                  np.asarray(r_un.centers))
    assert float(r_sh.radius2) == float(r_un.radius2)


def test_mesh_executor_sharded_bitwise_parity_single_device():
    # The streamed sharded MeshExecutor path on a 1-device mesh (the
    # multi-device grid lives in tests/test_distributed.py): mrg and the
    # streamed eim_sample must be bitwise the HostStream/device results.
    import jax
    from repro.core import MeshExecutor, eim_sample
    from repro.launch.mesh import make_mesh
    _, _, shard_source = _sharded_imports()
    x = _pts(n=1024, d=3, seed=10)
    mesh = make_mesh((1,), ("data",))
    me = MeshExecutor(mesh, block_rows=128)
    r_mesh = mrg(shard_source(HostSource(x), 1), 5, executor=me, impl="ref")
    r_host = mrg(HostSource(x), 5, executor=HostStreamExecutor(block_rows=128),
                 impl="ref")
    np.testing.assert_array_equal(np.asarray(r_mesh.centers),
                                  np.asarray(r_host.centers))
    assert float(r_mesh.radius2) == float(r_host.radius2)
    assert r_mesh.rounds == r_host.rounds
    n2 = 16384
    x2 = _pts(n=n2, d=3, seed=11)
    key = jax.random.PRNGKey(0)
    s_dev = eim_sample(jnp.asarray(x2), 4, key, impl="ref")
    s_mesh = eim_sample(HostSource(x2), 4, key, impl="ref",
                        executor=MeshExecutor(mesh, block_rows=2048))
    assert int(s_dev.iters) == int(s_mesh.iters)
    np.testing.assert_array_equal(np.asarray(s_dev.sample_mask),
                                  np.asarray(s_mesh.sample_mask))
    np.testing.assert_array_equal(np.asarray(s_dev.s_mask),
                                  np.asarray(s_mesh.s_mask))


def test_mesh_executor_rejects_mismatched_shard_count():
    from repro.core import MeshExecutor
    from repro.launch.mesh import make_mesh
    _, _, shard_source = _sharded_imports()
    sh = shard_source(HostSource(_pts(n=64, d=2)), 2)
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="shards"):
        mrg(sh, 4, executor=MeshExecutor(mesh, block_rows=16))


class _SpyShard(HostSource):
    """Per-host shard recording the largest single read it ever served and
    whether anything materialized it."""

    def __init__(self, x):
        super().__init__(x)
        self.max_read = 0
        self.materialized = False

    def host_blocks(self, block_rows):
        for blk in super().host_blocks(block_rows):
            self.max_read = max(self.max_read, blk.shape[0])
            yield blk

    def take(self, indices):
        out = super().take(indices)
        self.max_read = max(self.max_read, out.shape[0])
        return out

    def materialize(self):
        self.materialized = True
        return super().materialize()


def test_mesh_executor_sharded_never_materializes_full_n():
    # The no-full-n invariant, asserted via a source-read spy: under a
    # per-shard memory_budget no shard ever serves a read larger than the
    # budget-derived super-shard, and nothing calls materialize().
    from repro.core import MeshExecutor
    from repro.data import ShardedSource
    from repro.launch.mesh import make_mesh
    x = _pts(n=4096, d=3, seed=12)
    shards = [_SpyShard(x[i * 1024:(i + 1) * 1024]) for i in range(4)]
    budget = 64 * 1024
    mesh = make_mesh((1,), ("data",))
    # 4 shards on a 1-device mesh is a shard-count mismatch; spy through
    # the sequential executor for the read-size contract instead, then the
    # 1-shard mesh for the mesh path.
    ex = HostStreamExecutor(memory_budget=budget)
    sh = ShardedSource.from_per_host_shards(shards)
    rows = ex.rows_for(sh)
    assert rows * 4 * (sh.d + 1) * (1 + ex.prefetch) <= budget
    mrg(sh, 4, executor=ex, impl="ref")
    assert all(s.max_read <= rows for s in shards)
    assert not any(s.materialized for s in shards)
    spy = _SpyShard(x)
    me = MeshExecutor(mesh, memory_budget=budget)
    rows_me = me.rows_for(ShardedSource([spy]))
    mrg(ShardedSource([spy]), 4, executor=me, impl="ref")
    assert spy.max_read <= rows_me < spy.n
    assert not spy.materialized


# ---------------------------------------------------------------------------
# multi-process shard model (single-process behavior; the cross-process
# behavior is pinned by tests/distributed/)
# ---------------------------------------------------------------------------


def test_remote_shard_stubs_refuse_all_reads():
    from repro.data import RemoteShard
    rs = RemoteShard(128, 3, process=2)
    assert (rs.n, rs.d, rs.process) == (128, 3, 2)
    assert rs.is_remote
    for op in (lambda: next(iter(rs.blocks(32))),
               lambda: next(iter(rs.host_blocks(32))),
               lambda: rs.row(0),
               lambda: rs.take([0, 1]),
               lambda: rs.materialize()):
        with pytest.raises(RuntimeError, match="lives on process 2"):
            op()
    with pytest.raises(ValueError):
        RemoteShard(-1, 3)
    with pytest.raises(ValueError):
        RemoteShard(4, 0)


def test_process_sharded_source_for_process_layout():
    from repro.data import ProcessShardedSource, RemoteShard
    x = _pts(n=96, d=4, seed=21)
    local = HostSource(x[32:64])
    src = ProcessShardedSource.for_process(local, [32, 32, 32], 1)
    assert src.n == 96 and src.d == 4
    assert src.local_shard_ids == (1,)
    assert getattr(src.shards[0], "is_remote", False)
    assert getattr(src.shards[2], "is_remote", False)
    assert src.shards[0].process == 0 and src.shards[2].process == 2
    # take on locally-owned global rows resolves through the shard offset
    np.testing.assert_array_equal(src.take([32, 63]), x[[32, 63]])
    np.testing.assert_array_equal(src.row(40), x[40])
    # a remote row on a single-process runtime is unservable — hard error
    with pytest.raises(RuntimeError, match="single-process"):
        src.take([0])
    # size mismatch between the local shard and the global partition
    with pytest.raises(ValueError, match="must agree across processes"):
        ProcessShardedSource.for_process(local, [32, 16, 32], 1)
    with pytest.raises(ValueError, match="out of range"):
        ProcessShardedSource.for_process(local, [32, 32], 2)
    # all-remote construction can never fold anything locally
    with pytest.raises(ValueError, match="at least one local shard"):
        ProcessShardedSource([RemoteShard(8, 4, process=0),
                              RemoteShard(8, 4, process=1)])


def test_process_sharded_source_refused_on_single_process():
    # A source with remote shards on a single-process runtime is a launch
    # bug: no other process exists to feed the stubs. MeshExecutor must
    # report it as a configuration error up front (_local_ids), not as a
    # RemoteShard read crash deep inside a fold.
    from repro.core import MeshExecutor
    from repro.data import ProcessShardedSource
    from repro.launch.mesh import make_mesh
    x = _pts(n=64, d=3, seed=5)
    src = ProcessShardedSource.for_process(HostSource(x[:32]), [32, 32], 0)
    ex = MeshExecutor(make_mesh((1,), ("data",)), block_rows=16)
    with pytest.raises(ValueError, match="single-process"):
        ex._local_ids(src)
    # and the full driver surfaces a ValueError too (shard/mesh mismatch
    # or the remote-shard trap, depending on topology) — never a crash
    with pytest.raises(ValueError):
        mrg(src, 4, executor=ex)
