"""Streamed (out-of-core) EIM: sampler invariance + path parity.

Contracts under test (core/eim.py + core/executor.py + kernels/engine.py):

  * the counter-based per-row Bernoulli sampler is *blocking-invariant*:
    concatenating per-block draws over any partition of [0, n) is bitwise
    identical to one full-range draw (Philox keyed by absolute row index —
    this is what makes the sampled sets independent of the super-shard
    size), and runs identically eager vs jitted, legacy vs typed keys,
    with JAX_ENABLE_X64 off (pure uint32 limb arithmetic);
  * ``eim_sample`` over Array/Host/Memmap sources on ``HostStreamExecutor``
    (any ``block_rows``) and over ``SimExecutor``'s vmapped machines is
    **bitwise identical** to the jitted device path for the same key on
    the ref backend — masks, iteration count and overflow all match;
  * the streamed cross-block top-k merge equals the monolithic
    ``lax.top_k`` values;
  * EIM completes out-of-core: at an n whose (n, d) f32 array exceeds a
    stated device budget, the streamed path finishes with only
    budget-bounded super-shards device-resident;
  * the compact-buffer §4 bound raises instead of silently truncating.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HostStreamExecutor, SimExecutor, eim, eim_sample
from repro.core.eim import _sample_cap
from repro.data import (ArraySource, HostSource, MemmapSource,
                        SyntheticSource, synthetic_source)
from repro.kernels import engine, ops


def _pts(n, d=2, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


# n chosen so the sampling loop engages: threshold (4/ε)k·n^ε·ln n < n
N_SAMPLING, K, KEY_SEED = 20_000, 4, 1


@pytest.fixture(scope="module")
def device_sample():
    x = _pts(N_SAMPLING, seed=8)
    key = jax.random.PRNGKey(KEY_SEED)
    s = eim_sample(jnp.asarray(x), K, key, eps=0.1, phi=8.0, impl="ref")
    assert bool(s.sampled) and int(s.iters) >= 1
    return x, key, s


# ---------------------------------------------------------------------------
# counter-based sampler: blocking invariance + determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows", [1, 13, 999, 4096, 10_000])
def test_bernoulli_rows_blocking_invariance(rows):
    key = jax.random.PRNGKey(7)
    p = np.float32(0.3)
    full = np.asarray(engine.bernoulli_rows(key, 0, 10_000, p))
    parts = [np.asarray(engine.bernoulli_rows(key, s, min(rows, 10_000 - s), p))
             for s in range(0, 10_000, rows)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_uniform_rows_blocking_invariance_across_2_32_boundary():
    # global row indices are 64-bit: the uint32 counter carries into the
    # high word, so blocks may straddle the 2^32 row boundary
    key = jax.random.PRNGKey(3)
    start = (1 << 32) - 5
    whole = np.asarray(engine.uniform_rows(key, start, 10))
    lo = np.asarray(engine.uniform_rows(key, start, 5))
    hi = np.asarray(engine.uniform_rows(key, 1 << 32, 5))
    np.testing.assert_array_equal(np.concatenate([lo, hi]), whole)


def test_uniform_rows_key_forms_and_jit_agree():
    legacy = jax.random.PRNGKey(9)
    typed = jax.random.key(9)
    raw = np.asarray(legacy)                     # (2,) uint32 key data
    eager = np.asarray(engine.uniform_rows(legacy, 0, 512))
    for k in (typed, raw):
        np.testing.assert_array_equal(
            np.asarray(engine.uniform_rows(k, 0, 512)), eager)
    jitted = jax.jit(lambda k, p: engine.bernoulli_rows(k, 0, 512, p))
    np.testing.assert_array_equal(
        np.asarray(jitted(legacy, jnp.float32(0.25))),
        np.asarray(engine.bernoulli_rows(legacy, 0, 512, np.float32(0.25))))


def test_uniform_rows_distribution():
    u = np.asarray(engine.uniform_rows(jax.random.PRNGKey(0), 0, 200_000))
    assert 0.0 <= u.min() and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.005
    b = np.asarray(engine.bernoulli_rows(jax.random.PRNGKey(1), 0, 200_000,
                                         np.float32(0.1)))
    assert abs(b.mean() - 0.1) < 0.005


def test_uniform_rows_at_is_gather_of_full_range():
    # the gather-form sampler is the same pure function of (key, row id):
    # evaluating at arbitrary indices == indexing the full-range draw
    key = jax.random.PRNGKey(11)
    full = np.asarray(engine.uniform_rows(key, 0, 10_000))
    idx = np.sort(np.random.default_rng(3).choice(10_000, 3000,
                                                  replace=False))
    np.testing.assert_array_equal(
        np.asarray(engine.uniform_rows_at(key, idx)), full[idx])
    p = np.float32(0.25)
    np.testing.assert_array_equal(
        np.asarray(engine.bernoulli_rows_at(key, idx, p)),
        np.asarray(engine.bernoulli_rows(key, 0, 10_000, p))[idx])


def test_uniform_rows_at_crosses_2_32_boundary():
    # indices are 64-bit: the split into uint32 counter words must carry
    key = jax.random.PRNGKey(3)
    idx = np.array([(1 << 32) - 2, (1 << 32) - 1, 1 << 32, (1 << 32) + 1],
                   np.uint64)
    whole = np.asarray(engine.uniform_rows(key, (1 << 32) - 2, 4))
    np.testing.assert_array_equal(
        np.asarray(engine.uniform_rows_at(key, idx)), whole)


def test_bernoulli_rows_at_block_padded_operands_agree():
    # the jitted fixed-shape block form (padded index words as operands)
    # must agree with the unjitted gather form on the live lanes
    key = jax.random.PRNGKey(5)
    idx = np.array([3, 17, 256, 9000], np.uint64)
    lo, hi = engine.split_index_words(idx)
    lo = np.pad(lo, (0, 4))     # pad to a fixed 8-lane block
    hi = np.pad(hi, (0, 4))
    got = np.asarray(engine.bernoulli_rows_at_block(key, lo, hi,
                                                    np.float32(0.4)))[:4]
    want = np.asarray(engine.bernoulli_rows_at(key, idx, np.float32(0.4)))
    np.testing.assert_array_equal(got, want)


def test_fold_top_k_matches_monolithic():
    v = _pts(3000, d=1, seed=4).reshape(-1)
    want = np.asarray(jax.lax.top_k(jnp.asarray(v), 17)[0])
    got = np.asarray(engine.fold_top_k([v[:100], v[100:1234], v[1234:]], 17))
    np.testing.assert_array_equal(got, want)
    # fewer values than k: sentinel padding survives the merge
    short = np.asarray(engine.fold_top_k([v[:5]], 9))
    assert (short[5:] <= -3e38).all()


# ---------------------------------------------------------------------------
# streamed eim_sample == device path, bitwise (the ISSUE acceptance bar)
# ---------------------------------------------------------------------------

def _assert_sample_equal(dev, got):
    np.testing.assert_array_equal(np.asarray(dev.sample_mask),
                                  np.asarray(got.sample_mask))
    np.testing.assert_array_equal(np.asarray(dev.s_mask),
                                  np.asarray(got.s_mask))
    assert int(dev.iters) == int(got.iters)
    assert int(dev.overflow) == int(got.overflow)
    assert bool(dev.sampled) == bool(got.sampled)


@pytest.mark.parametrize("block_rows", [1000, 3777, 8192, 50_000])
def test_eim_sample_host_stream_bitwise_any_blocking(device_sample,
                                                     block_rows):
    # the sampler is counter-based and the d(x,S)/pivot folds are value
    # reductions, so parity holds for *any* super-shard size — not just
    # the device blocking
    x, key, dev = device_sample
    got = eim_sample(HostSource(x), K, key, eps=0.1, phi=8.0, impl="ref",
                     executor=HostStreamExecutor(block_rows=block_rows))
    _assert_sample_equal(dev, got)


def test_eim_sample_memmap_bitwise(tmp_path, device_sample):
    x, key, dev = device_sample
    src = MemmapSource.save_shards(x, tmp_path, rows_per_shard=1500)
    got = eim_sample(src, K, key, eps=0.1, phi=8.0, impl="ref",
                     executor=HostStreamExecutor(block_rows=4096))
    _assert_sample_equal(dev, got)


def test_eim_sample_sim_executor_bitwise(device_sample):
    # SimExecutor keeps the vmapped-machines simulation; its per-machine
    # top-k merge is the simulated shuffle and must reduce the same pivot
    x, key, dev = device_sample
    got = eim_sample(ArraySource(x), K, key, eps=0.1, phi=8.0, impl="ref",
                     executor=SimExecutor(m=8))
    _assert_sample_equal(dev, got)


# ---------------------------------------------------------------------------
# compacted-R parity grid: compact_threshold ∈ {0 never, 0.5, 1 always} ×
# Host/Memmap/Synthetic sources × block_rows — all bitwise vs the device path
# ---------------------------------------------------------------------------

THRESHOLDS = [0.0, 0.5, 1.0]


@pytest.mark.parametrize("compact_threshold", THRESHOLDS)
@pytest.mark.parametrize("block_rows", [3777, 8192])
def test_eim_sample_compacted_host_bitwise(device_sample, compact_threshold,
                                           block_rows):
    # Round-1 draws are keyed by *original* row ids and the fold rounds
    # are per-row/value reductions, so the sample is invariant to
    # whether/when the relation was compacted into an IndexedSource view
    x, key, dev = device_sample
    got = eim_sample(HostSource(x), K, key, eps=0.1, phi=8.0, impl="ref",
                     executor=HostStreamExecutor(block_rows=block_rows),
                     compact_threshold=compact_threshold)
    _assert_sample_equal(dev, got)


@pytest.mark.parametrize("compact_threshold", THRESHOLDS)
def test_eim_sample_compacted_memmap_bitwise(tmp_path, device_sample,
                                             compact_threshold):
    x, key, dev = device_sample
    src = MemmapSource.save_shards(x, tmp_path, rows_per_shard=1500)
    got = eim_sample(src, K, key, eps=0.1, phi=8.0, impl="ref",
                     executor=HostStreamExecutor(block_rows=4096),
                     compact_threshold=compact_threshold)
    _assert_sample_equal(dev, got)


@pytest.mark.parametrize("compact_threshold", THRESHOLDS)
def test_eim_sample_compacted_synthetic_bitwise(device_sample,
                                                compact_threshold):
    # generator-backed source: the view's gathers regenerate runs on the
    # host — the sample must still be bitwise the device path's
    x, key, dev = device_sample
    src = SyntheticSource(lambda start, rows: x[start:start + rows],
                          N_SAMPLING, x.shape[1], name="fixture")
    got = eim_sample(src, K, key, eps=0.1, phi=8.0, impl="ref",
                     executor=HostStreamExecutor(block_rows=4096),
                     compact_threshold=compact_threshold)
    _assert_sample_equal(dev, got)


@pytest.mark.parametrize("compact_threshold", [0.5, 1.0])
def test_eim_sample_compacted_sim_executor_bitwise(device_sample,
                                                   compact_threshold):
    # SimExecutor re-materializes its blocked cache per view object (the
    # weakref key changes on every compaction switch) — stale-state-free
    x, key, dev = device_sample
    got = eim_sample(ArraySource(x), K, key, eps=0.1, phi=8.0, impl="ref",
                     executor=SimExecutor(m=8),
                     compact_threshold=compact_threshold)
    _assert_sample_equal(dev, got)


class _MeteredSource(HostSource):
    """HostSource that counts rows served per blocks() pass and via take."""

    def __init__(self, x):
        super().__init__(x)
        self.pass_rows = []        # rows yielded per blocks() stream
        self.take_rows = 0
        self.max_block = 0

    def host_blocks(self, block_rows):
        self.pass_rows.append(0)
        for blk in super().host_blocks(block_rows):
            self.pass_rows[-1] += blk.shape[0]
            self.max_block = max(self.max_block, blk.shape[0])
            yield blk

    def take(self, indices):
        out = super().take(indices)
        self.take_rows += out.shape[0]
        self.max_block = max(self.max_block, out.shape[0])
        return out


class _MeteredExecutor(HostStreamExecutor):
    """Records the view size (rows the pass touches) per filter round."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.filter_pass_rows = []

    def run_filter_round(self, source, *a, **kw):
        self.filter_pass_rows.append(source.n)
        return super().run_filter_round(source, *a, **kw)


def test_eim_compaction_shrinks_per_iteration_pass_rows(device_sample):
    # the tentpole's point: with compaction the fold's per-iteration pass
    # touches |R∪H| rows, not n — and the view's gathers stay under the
    # executor's block budget
    x, key, dev = device_sample
    rows = 4096
    ex0 = _MeteredExecutor(block_rows=rows)
    eim_sample(HostSource(x), K, key, eps=0.1, phi=8.0, impl="ref",
               executor=ex0, compact_threshold=0.0)
    src = _MeteredSource(x)
    ex1 = _MeteredExecutor(block_rows=rows)
    got = eim_sample(src, K, key, eps=0.1, phi=8.0, impl="ref",
                     executor=ex1, compact_threshold=1.0)
    _assert_sample_equal(dev, got)
    iters = int(dev.iters)
    # baseline: every filter pass touches all n rows, T times
    assert ex0.filter_pass_rows == [N_SAMPLING] * iters
    # compacted: the first pass sees all n, every later pass the shrunken
    # view — monotone non-increasing and strictly below n by the end
    passes = ex1.filter_pass_rows
    assert len(passes) == iters
    assert passes[0] == N_SAMPLING
    assert all(a >= b for a, b in zip(passes, passes[1:]))
    assert passes[-1] < N_SAMPLING
    assert sum(passes) < iters * N_SAMPLING
    # out-of-core discipline holds during the view's gathers: every block
    # DMA'd (directly or via IndexedSource.take) is within the budget
    assert src.max_block <= rows


def test_eim_compact_threshold_validation():
    x = _pts(1000, seed=3)
    with pytest.raises(ValueError, match="compact_threshold"):
        eim_sample(HostSource(x), 4, jax.random.PRNGKey(0),
                   compact_threshold=1.5)
    with pytest.raises(ValueError, match="compact_threshold"):
        eim(HostSource(x), 4, jax.random.PRNGKey(0), compact_threshold=-0.1)


def test_eim_full_streamed_bitwise(device_sample):
    x, key, _ = device_sample
    r_dev = eim(jnp.asarray(x), K, key, impl="ref")
    r_str = eim(HostSource(x), K, key, impl="ref",
                executor=HostStreamExecutor(block_rows=2048))
    np.testing.assert_array_equal(np.asarray(r_dev.centers),
                                  np.asarray(r_str.centers))
    assert float(r_dev.radius2) == float(r_str.radius2)
    _assert_sample_equal(r_dev.sample, r_str.sample)


def test_eim_radius2_is_exact_squared_fold(device_sample):
    # radius2 must be max(min_d2) exactly — no sqrt(d2)→r*r f32 round-trip
    # — on the device path and every executor path (they move together)
    x, key, _ = device_sample
    for res in (eim(jnp.asarray(x), K, key, impl="ref"),
                eim(HostSource(x), K, key, impl="ref",
                    executor=HostStreamExecutor(block_rows=4096)),
                eim(ArraySource(x), K, key, impl="ref",
                    executor=SimExecutor(m=8))):
        _, d2 = ops.assign_nearest(jnp.asarray(x), res.centers, impl="ref")
        assert float(res.radius2) == float(jnp.max(d2))


def test_eim_degenerate_small_n_streamed():
    # below the threshold the loop never runs: C = everything, EIM == GON;
    # the streamed path must degrade identically
    x = _pts(500, d=3, seed=7)
    key = jax.random.PRNGKey(0)
    r_dev = eim(jnp.asarray(x), 8, key, impl="ref")
    r_str = eim(HostSource(x), 8, key, impl="ref",
                executor=HostStreamExecutor(block_rows=100))
    assert not bool(r_str.sample.sampled)
    np.testing.assert_array_equal(np.asarray(r_dev.centers),
                                  np.asarray(r_str.centers))
    assert float(r_dev.radius2) == float(r_str.radius2)


# ---------------------------------------------------------------------------
# out-of-core: EIM past a stated device budget
# ---------------------------------------------------------------------------

def test_eim_completes_past_device_budget():
    # the stated HBM budget cannot hold the (n, d) f32 points, so the
    # legacy materializing path is structurally impossible; the streamed
    # path completes with super-shards bounded well under the budget
    n, d, k = 65_536, 8, 4
    device_budget = 1 << 20                       # 1 MiB
    assert 4 * n * d > device_budget
    src = synthetic_source("unif", n, d=d, seed=5)
    ex = HostStreamExecutor(memory_budget=device_budget // 4)
    rows = ex.rows_for(src)
    assert 4 * rows * d * (1 + ex.prefetch) <= device_budget
    res = eim(src, k, jax.random.PRNGKey(2), impl="ref", executor=ex)
    assert bool(res.sample.sampled) and int(res.sample.iters) >= 1
    assert res.centers.shape == (k, d)
    assert float(res.radius2) > 0.0
    # paper-§4 size bound on the compacted sample actually held
    pop = int(np.asarray(res.sample.sample_mask).sum())
    s_count = int(np.asarray(res.sample.s_mask).sum())
    assert pop <= _sample_cap(n, k, 0.1, s_count)


def test_eim_streamed_rejects_uncompacted():
    x = _pts(1000, d=2, seed=1)
    with pytest.raises(ValueError, match="compact"):
        eim(HostSource(x), 4, jax.random.PRNGKey(0), compact=False)


def test_eim_rejects_executor_without_filter_round():
    # An executor without the per-iteration hook (a bare Executor subclass
    # — every built-in executor implements it now, MeshExecutor included
    # via the sharded streamed path) must fail fast, not mid-run.
    from repro.core import Executor

    class _NoFilterExecutor(Executor):
        pass

    x = _pts(1000, d=2, seed=2)
    with pytest.raises(NotImplementedError, match="run_filter_round"):
        eim_sample(HostSource(x), 4, jax.random.PRNGKey(0),
                   executor=_NoFilterExecutor())


# ---------------------------------------------------------------------------
# compact-buffer bound: hard error, not silent truncation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("streamed", [False, True])
def test_eim_compact_cap_hard_error(streamed, device_sample):
    # max_iters=0 in the sampling regime leaves |R| = n > threshold, so
    # |C| exceeds the §4 bound (4/ε)k·n^ε·log n + |S| — both paths must
    # refuse to truncate
    x, key, _ = device_sample
    points = HostSource(x) if streamed else jnp.asarray(x)
    with pytest.raises(RuntimeError, match="max_iters"):
        eim(points, K, key, impl="ref", max_iters=0)
