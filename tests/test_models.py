"""Model zoo tests: per-arch smoke + decode/forward consistency + SSD oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data import model_batch
from repro.models import (decode_step, forward, init_params,
                          prefill)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16, seed=0):
    b = model_batch(cfg, B, S, seed=seed)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_one_train_shape(arch):
    cfg = get_config(arch, smoke=True)
    batch = _batch(cfg)
    logits, aux = forward(params_cache(arch), batch, cfg)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch


_PARAMS = {}


def params_cache(arch):
    if arch not in _PARAMS:
        cfg = get_config(arch, smoke=True)
        _PARAMS[arch] = init_params(KEY, cfg)
    return _PARAMS[arch]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    """Teacher-forcing consistency: decoding token t against the prefilled
    cache must reproduce forward()'s logits at position t."""
    cfg = get_config(arch, smoke=True)
    params = params_cache(arch)
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    logits_full, _ = forward(params, batch, cfg)

    pre = {k: (v[:, : S - 1] if k in ("tokens", "labels") else v)
           for k, v in batch.items()}
    last, cache = prefill(params, pre, cfg, S_max=S + 4)
    # prefill's last logits == forward logits at position S-2
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits_full[:, S - 2]),
                               rtol=2e-2, atol=2e-2)
    # decode the S-1'th token
    tok = batch["tokens"][:, S - 1 : S]
    dec, cache = decode_step(params, cache, tok, cfg)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(logits_full[:, S - 1]),
                               rtol=2e-2, atol=2e-2)


def test_ssd_chunked_matches_sequential_scan():
    """Chunked SSD == naive per-step linear recurrence."""
    from repro.configs import mamba2_370m
    from repro.models.layers import ssd_chunked

    cfg = mamba2_370m.smoke().replace(ssm_chunk=4)
    B, S, nh, P, N = 2, 16, 4, 8, 8
    rng = np.random.default_rng(0)
    xh = jnp.asarray(rng.normal(size=(B, S, nh, P)).astype(np.float32))
    dtp = jnp.asarray(rng.uniform(0.1, 0.9, (B, S, nh)).astype(np.float32))
    A = jnp.asarray(-rng.uniform(0.1, 1.0, (nh,)).astype(np.float32))
    Bc = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cc = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))

    y, h_last = ssd_chunked(xh, dtp, A, Bc, Cc, cfg)

    # oracle: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t h_t
    h = np.zeros((B, nh, N, P))
    ys = []
    for t in range(S):
        da = np.exp(np.asarray(dtp[:, t]) * np.asarray(A)[None, :])
        bx = np.einsum("bn,bhp->bhnp", np.asarray(Bc[:, t]),
                       np.asarray(xh[:, t]) * np.asarray(dtp[:, t])[..., None])
        h = h * da[:, :, None, None] + bx
        ys.append(np.einsum("bn,bhnp->bhp", np.asarray(Cc[:, t]), h))
    want = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_last), h, rtol=1e-3, atol=1e-3)


def test_mrope_reduces_to_rope_for_text():
    """With all three position components equal, M-RoPE == plain RoPE."""
    from repro.models.layers import mrope_cos_sin, rope_cos_sin
    pos = jnp.arange(10)[None, :].astype(jnp.int32)      # (1,10)
    pos3 = jnp.broadcast_to(pos[None], (3, 1, 10))
    c1, s1 = rope_cos_sin(pos, 16, 1e4)
    c2, s2 = mrope_cos_sin(pos3, (2, 3, 3), 16, 1e4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_sliding_window_masks_decode():
    """Hymba decode: window layers must not attend beyond the window."""
    from repro.models.layers import _mask_block
    q_pos = jnp.asarray([[10]])
    k_idx = jnp.arange(16)
    m_global = np.asarray(_mask_block(q_pos, k_idx, jnp.int32(0), False))
    m_window = np.asarray(_mask_block(q_pos, k_idx, jnp.int32(4), False))
    assert m_global[0, 0, :11].all() and not m_global[0, 0, 11:].any()
    assert m_window[0, 0, 7:11].all()
    assert not m_window[0, 0, :7].any()


def test_moe_spmd_matches_local_math():
    """The shard_map MoE partial-sum equals the single-device path."""
    from repro.models.layers import _moe_math
    from repro.configs import dbrx_132b
    cfg = dbrx_132b.smoke()
    rng = np.random.default_rng(1)
    N, D, E, F = 32, cfg.d_model, cfg.num_experts, cfg.d_ff
    xf = jnp.asarray(rng.normal(size=(N, D)).astype(np.float32))
    router = jnp.asarray(rng.normal(size=(D, E)).astype(np.float32) * 0.1)
    wg = jnp.asarray(rng.normal(size=(E, D, F)).astype(np.float32) * 0.05)
    wu = jnp.asarray(rng.normal(size=(E, D, F)).astype(np.float32) * 0.05)
    wd = jnp.asarray(rng.normal(size=(E, F, D)).astype(np.float32) * 0.05)
    full, _ = _moe_math(xf, router, wg, wu, wd, cfg, 1.25, 0, E)
    # simulate 2 expert shards and sum their partials
    half = E // 2
    p1, _ = _moe_math(xf, router, wg[:half], wu[:half], wd[:half], cfg,
                      1.25, 0, half)
    p2, _ = _moe_math(xf, router, wg[half:], wu[half:], wd[half:], cfg,
                      1.25, half, half)
    np.testing.assert_allclose(np.asarray(p1 + p2), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
