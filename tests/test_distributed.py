"""Distributed behaviors on simulated multi-device hosts.

Each test runs in a subprocess with ``--xla_force_host_platform_device_count``
so the main pytest process keeps its single-device view (the dry-run is
the only other place placeholder devices are created).
"""
import json
import subprocess
import sys
import textwrap


BASE = dict(PYTHONPATH="src")


def _run(body: str, devices: int = 8, timeout: int = 600) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax, json
        import jax.numpy as jnp
        import numpy as np
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    import os
    env = dict(os.environ)
    env.update(BASE)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mrg_distributed_matches_quality():
    out = _run("""
        from repro.core import mrg_distributed, gonzalez
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        pts = jnp.asarray(np.random.default_rng(0)
                          .normal(size=(800, 4)).astype(np.float32))
        c, r2 = mrg_distributed(pts, 6, mesh, shard_axes=("data",))
        g = gonzalez(pts, 6)
        ratio = float(jnp.sqrt(r2)) / float(jnp.sqrt(g.radius2))
        print(json.dumps({"ratio": ratio}))
    """)
    ratio = json.loads(out.strip().splitlines()[-1])["ratio"]
    assert ratio <= 2.0 + 1e-6  # MRG<=4·OPT, GON>=OPT ⇒ ratio<=4; usually ~1


def test_mrg_hierarchical_multi_axis():
    out = _run("""
        from repro.core import mrg_distributed, gonzalez
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        pts = jnp.asarray(np.random.default_rng(1)
                          .normal(size=(960, 3)).astype(np.float32))
        c, r2 = mrg_distributed(pts, 5, mesh,
                                shard_axes=("pod", "data", "model"),
                                hierarchical=True)
        g = gonzalez(pts, 5)
        print(json.dumps({"ratio": float(jnp.sqrt(r2) /
                                         jnp.sqrt(g.radius2))}))
    """)
    ratio = json.loads(out.strip().splitlines()[-1])["ratio"]
    # hierarchical gather adds +2 per level (paper Lemma 3)
    assert ratio <= 8.0


def test_mesh_executor_hierarchical_vs_flat():
    """The MeshExecutor form of the hierarchical Lemma-3 path: per-axis
    gathers with an intermediate GON per level, vs the flat single gather,
    on the same 8-device mesh — wrapper and executor must agree exactly,
    rounds accounting must reflect the gather tree depth, and both centers
    sets must satisfy the covering bound."""
    out = _run("""
        from repro.core import MeshExecutor, gonzalez, mrg, mrg_distributed
        from repro.data import ArraySource
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        axes = ("pod", "data", "model")
        pts = np.random.default_rng(2).normal(size=(960, 3)).astype(np.float32)
        pj = jnp.asarray(pts)
        res_h = mrg(ArraySource(pts), 5,
                    executor=MeshExecutor(mesh, shard_axes=axes,
                                          hierarchical=True))
        res_f = mrg(ArraySource(pts), 5,
                    executor=MeshExecutor(mesh, shard_axes=axes))
        cw, r2w = mrg_distributed(pj, 5, mesh, shard_axes=axes,
                                  hierarchical=True)
        g = gonzalez(pj, 5)
        print(json.dumps({
            "rounds_h": res_h.rounds, "rounds_f": res_f.rounds,
            "wrapper_equal": bool((np.asarray(res_h.centers)
                                   == np.asarray(cw)).all()
                                  and float(res_h.radius2) == float(r2w)),
            "ratio_h": float(jnp.sqrt(res_h.radius2) / jnp.sqrt(g.radius2)),
            "ratio_f": float(jnp.sqrt(res_f.radius2) / jnp.sqrt(g.radius2)),
        }))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    # one GON level per gathered axis (+ round 1) vs the classic 2 rounds
    assert r["rounds_h"] == 4 and r["rounds_f"] == 2
    assert r["wrapper_equal"]  # mrg_distributed is a thin MeshExecutor shim
    # Lemma 3: +2 approx per extra level (4 levels -> <=8·OPT); flat is
    # the classic 2-round 4-approx. GON >= OPT makes these checkable.
    assert r["ratio_h"] <= 8.0 and r["ratio_f"] <= 4.0


def test_sharded_source_mesh_mrg_bitwise_parity_grid():
    """The tentpole contract: ``mrg`` over a ``ShardedSource`` on the
    streamed ``MeshExecutor`` is *bitwise identical* to the
    HostStreamExecutor run for every shard count × block_rows cell (same
    machine blocking ⇒ same centers, radius, rounds), and — with one
    block per shard — to ``mrg_sim``'s m-machine blocking too."""
    out = _run("""
        from repro import compat
        from repro.core import HostStreamExecutor, MeshExecutor, mrg, mrg_sim
        from repro.data import HostSource, shard_source
        n, d, k = 4096, 3, 5
        x = np.random.default_rng(2).normal(size=(n, d)).astype(np.float32)
        cells = []
        for S in (1, 2, 4, 8):
            mesh = compat.make_mesh(np.array(jax.devices()[:S]), ("data",))
            per = n // S
            for r in (512, per):
                me = MeshExecutor(mesh, block_rows=r)
                rm = mrg(shard_source(HostSource(x), S), k, executor=me,
                         impl="ref")
                rh = mrg(HostSource(x), k,
                         executor=HostStreamExecutor(block_rows=r),
                         impl="ref")
                cells.append({
                    "S": S, "rows": r,
                    "host_exact": bool(
                        (np.asarray(rm.centers) == np.asarray(rh.centers))
                        .all() and float(rm.radius2) == float(rh.radius2)
                        and rm.rounds == rh.rounds)})
            rs = mrg_sim(jnp.asarray(x), k, m=S, impl="ref")
            rm = mrg(shard_source(HostSource(x), S), k,
                     executor=MeshExecutor(mesh, block_rows=per), impl="ref")
            cells.append({
                "S": S, "rows": "per-vs-sim",
                "host_exact": bool(
                    (np.asarray(rm.centers) == np.asarray(rs.centers)).all()
                    and float(rm.radius2) == float(rs.radius2))})
        print(json.dumps(cells))
    """)
    cells = json.loads(out.strip().splitlines()[-1])
    assert len(cells) == 12
    bad = [c for c in cells if not c["host_exact"]]
    assert not bad, f"sharded mesh MRG drifted in cells: {bad}"


def test_sharded_source_mesh_eim_bitwise_parity_and_budget():
    """Streamed EIM over per-host shards on a 4-way mesh: bitwise the
    device-path sample and the HostStream result for the same key; and the
    no-full-n invariant — under a per-shard ``memory_budget``, a
    source-read spy sees no read larger than the budget-derived
    super-shard and no ``materialize()`` call. Also covers multi-axis
    sharding (P over ("pod", "data"))."""
    out = _run("""
        from repro import compat
        from repro.core import (HostStreamExecutor, MeshExecutor, eim,
                                eim_sample, mrg)
        from repro.data import HostSource, ShardedSource, shard_source

        class SpyShard(HostSource):
            def __init__(self, x):
                super().__init__(x)
                self.max_read = 0
                self.materialized = False
            def host_blocks(self, block_rows):
                for blk in super().host_blocks(block_rows):
                    self.max_read = max(self.max_read, blk.shape[0])
                    yield blk
            def take(self, indices):
                out = super().take(indices)
                self.max_read = max(self.max_read, out.shape[0])
                return out
            def materialize(self):
                self.materialized = True
                return super().materialize()

        n, d, k = 16384, 3, 4
        x = np.random.default_rng(3).normal(size=(n, d)).astype(np.float32)
        key = jax.random.PRNGKey(7)
        mesh = compat.make_mesh(np.array(jax.devices()[:4]), ("data",))
        shards = [SpyShard(x[i * 4096:(i + 1) * 4096]) for i in range(4)]
        sh = ShardedSource.from_per_host_shards(shards)
        budget = 96 * 1024
        me = MeshExecutor(mesh, memory_budget=budget)
        rows = me.rows_for(sh)
        s_dev = eim_sample(jnp.asarray(x), k, key, impl="ref")
        e_mesh = eim(sh, k, key, impl="ref", executor=me)
        e_host = eim(HostSource(x), k, key, impl="ref",
                     executor=HostStreamExecutor(memory_budget=budget))
        # multi-axis: (2, 2) mesh sharded over both axes == 4 machines
        mesh22 = compat.make_mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                                  ("pod", "data"))
        me22 = MeshExecutor(mesh22, shard_axes=("pod", "data"),
                            block_rows=512)
        rm = mrg(shard_source(HostSource(x), me22), k, executor=me22,
                 impl="ref")
        rh = mrg(HostSource(x), k,
                 executor=HostStreamExecutor(block_rows=512), impl="ref")
        print(json.dumps({
            "budget_model_ok": rows * 4 * (d + 1) * (1 + me.prefetch)
                               <= budget,
            "rows": rows,
            "max_reads": [s.max_read for s in shards],
            "materialized": any(s.materialized for s in shards),
            "sample_exact": bool(
                np.array_equal(np.asarray(s_dev.sample_mask),
                               np.asarray(e_mesh.sample.sample_mask))
                and int(s_dev.iters) == int(e_mesh.sample.iters)),
            "eim_exact": bool(
                (np.asarray(e_mesh.centers)
                 == np.asarray(e_host.centers)).all()
                and float(e_mesh.radius2) == float(e_host.radius2)),
            "multiaxis_exact": bool(
                (np.asarray(rm.centers) == np.asarray(rh.centers)).all()
                and float(rm.radius2) == float(rh.radius2)),
        }))
    """, devices=4)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["budget_model_ok"], r
    assert all(m <= r["rows"] for m in r["max_reads"]), r
    assert not r["materialized"], "a shard was materialized on the mesh path"
    assert r["sample_exact"], "mesh EIM sample drifted from the device path"
    assert r["eim_exact"], "mesh eim() drifted from the HostStream path"
    assert r["multiaxis_exact"], "multi-axis sharded MRG drifted"


def test_sharded_train_step_runs_and_matches_single_device_loss():
    out = _run("""
        from repro.configs import get_config
        from repro.data import model_batch
        from repro.launch.mesh import make_mesh
        from repro.optim import adamw, make_schedule
        from repro.sharding import (batch_pspecs, shardings, state_pspecs,
                                    use_mesh)
        from repro.train import init_train_state, make_train_step
        cfg = get_config("granite_3_2b", smoke=True)
        opt = adamw(make_schedule("constant", peak=1e-3))
        batch = {k: jnp.asarray(v) for k, v in
                 model_batch(cfg, 8, 16).items()}
        # single device
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        _, m1 = jax.jit(make_train_step(cfg, opt))(state, batch)
        # 4x2 mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        with use_mesh(mesh):
            state2 = init_train_state(jax.random.PRNGKey(0), cfg, opt)
            st_sh = shardings(state_pspecs(jax.eval_shape(lambda: state2),
                                           mesh), mesh)
            step = jax.jit(make_train_step(cfg, opt))
            _, m2 = step(state2, batch)
        print(json.dumps({"l1": float(m1["loss"]), "l2": float(m2["loss"])}))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert abs(r["l1"] - r["l2"]) < 1e-2, r


def test_elastic_checkpoint_restore_smaller_mesh(tmp_path):
    out = _run(f"""
        from repro.configs import get_config
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        from repro.launch.mesh import make_mesh
        from repro.optim import adamw, make_schedule
        from repro.sharding import use_mesh
        from repro.train import init_train_state, make_train_step
        from repro.data import model_batch
        cfg = get_config("qwen2_0_5b", smoke=True)
        opt = adamw(make_schedule("constant", peak=1e-3))
        mesh8 = make_mesh((4, 2), ("data", "model"))
        batch = {{k: jnp.asarray(v) for k, v in
                 model_batch(cfg, 8, 16).items()}}
        with use_mesh(mesh8):
            state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
            state, m = jax.jit(make_train_step(cfg, opt))(state, batch)
            save_checkpoint("{tmp_path}", 1, state)
        # restore on a smaller (2,2) mesh — degraded pod
        mesh4 = make_mesh((2, 2), ("data", "model"))
        with use_mesh(mesh4):
            template = jax.tree.map(np.asarray,
                                    init_train_state(jax.random.PRNGKey(0),
                                                     cfg, opt))
            step, host = restore_checkpoint("{tmp_path}", template)
            state2 = jax.tree.map(jnp.asarray, host)
            state2, m2 = jax.jit(make_train_step(cfg, opt))(state2, batch)
        print(json.dumps({{"step": step, "loss": float(m2["loss"])}}))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["step"] == 1 and r["loss"] > 0


def test_moe_shard_map_vs_local():
    out = _run("""
        from repro.configs import get_config
        from repro.models import init_params, forward
        from repro.launch.mesh import make_mesh
        from repro.sharding import use_mesh
        cfg = get_config("dbrx_132b", smoke=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.arange(32).reshape(2, 16) % cfg.vocab_size}
        l1, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
        mesh = make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            l2, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
        err = float(jnp.max(jnp.abs(l1 - l2)))
        print(json.dumps({"err": err}))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["err"] < 1e-3, r
