"""Distributed behaviors on simulated multi-device hosts.

Each test runs in a subprocess with ``--xla_force_host_platform_device_count``
so the main pytest process keeps its single-device view (the dry-run is
the only other place placeholder devices are created).
"""
import json
import subprocess
import sys
import textwrap

import pytest

BASE = dict(PYTHONPATH="src")


def _run(body: str, devices: int = 8, timeout: int = 600) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax, json
        import jax.numpy as jnp
        import numpy as np
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    import os
    env = dict(os.environ)
    env.update(BASE)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mrg_distributed_matches_quality():
    out = _run("""
        from repro.core import mrg_distributed, gonzalez
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        pts = jnp.asarray(np.random.default_rng(0)
                          .normal(size=(800, 4)).astype(np.float32))
        c, r2 = mrg_distributed(pts, 6, mesh, shard_axes=("data",))
        g = gonzalez(pts, 6)
        ratio = float(jnp.sqrt(r2)) / float(jnp.sqrt(g.radius2))
        print(json.dumps({"ratio": ratio}))
    """)
    ratio = json.loads(out.strip().splitlines()[-1])["ratio"]
    assert ratio <= 2.0 + 1e-6  # MRG<=4·OPT, GON>=OPT ⇒ ratio<=4; usually ~1


def test_mrg_hierarchical_multi_axis():
    out = _run("""
        from repro.core import mrg_distributed, gonzalez
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        pts = jnp.asarray(np.random.default_rng(1)
                          .normal(size=(960, 3)).astype(np.float32))
        c, r2 = mrg_distributed(pts, 5, mesh,
                                shard_axes=("pod", "data", "model"),
                                hierarchical=True)
        g = gonzalez(pts, 5)
        print(json.dumps({"ratio": float(jnp.sqrt(r2) /
                                         jnp.sqrt(g.radius2))}))
    """)
    ratio = json.loads(out.strip().splitlines()[-1])["ratio"]
    # hierarchical gather adds +2 per level (paper Lemma 3)
    assert ratio <= 8.0


def test_mesh_executor_hierarchical_vs_flat():
    """The MeshExecutor form of the hierarchical Lemma-3 path: per-axis
    gathers with an intermediate GON per level, vs the flat single gather,
    on the same 8-device mesh — wrapper and executor must agree exactly,
    rounds accounting must reflect the gather tree depth, and both centers
    sets must satisfy the covering bound."""
    out = _run("""
        from repro.core import MeshExecutor, gonzalez, mrg, mrg_distributed
        from repro.data import ArraySource
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        axes = ("pod", "data", "model")
        pts = np.random.default_rng(2).normal(size=(960, 3)).astype(np.float32)
        pj = jnp.asarray(pts)
        res_h = mrg(ArraySource(pts), 5,
                    executor=MeshExecutor(mesh, shard_axes=axes,
                                          hierarchical=True))
        res_f = mrg(ArraySource(pts), 5,
                    executor=MeshExecutor(mesh, shard_axes=axes))
        cw, r2w = mrg_distributed(pj, 5, mesh, shard_axes=axes,
                                  hierarchical=True)
        g = gonzalez(pj, 5)
        print(json.dumps({
            "rounds_h": res_h.rounds, "rounds_f": res_f.rounds,
            "wrapper_equal": bool((np.asarray(res_h.centers)
                                   == np.asarray(cw)).all()
                                  and float(res_h.radius2) == float(r2w)),
            "ratio_h": float(jnp.sqrt(res_h.radius2) / jnp.sqrt(g.radius2)),
            "ratio_f": float(jnp.sqrt(res_f.radius2) / jnp.sqrt(g.radius2)),
        }))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    # one GON level per gathered axis (+ round 1) vs the classic 2 rounds
    assert r["rounds_h"] == 4 and r["rounds_f"] == 2
    assert r["wrapper_equal"]  # mrg_distributed is a thin MeshExecutor shim
    # Lemma 3: +2 approx per extra level (4 levels -> <=8·OPT); flat is
    # the classic 2-round 4-approx. GON >= OPT makes these checkable.
    assert r["ratio_h"] <= 8.0 and r["ratio_f"] <= 4.0


def test_sharded_train_step_runs_and_matches_single_device_loss():
    out = _run("""
        from repro.configs import get_config
        from repro.data import model_batch
        from repro.launch.mesh import make_mesh
        from repro.optim import adamw, make_schedule
        from repro.sharding import (batch_pspecs, shardings, state_pspecs,
                                    use_mesh)
        from repro.train import init_train_state, make_train_step
        cfg = get_config("granite_3_2b", smoke=True)
        opt = adamw(make_schedule("constant", peak=1e-3))
        batch = {k: jnp.asarray(v) for k, v in
                 model_batch(cfg, 8, 16).items()}
        # single device
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        _, m1 = jax.jit(make_train_step(cfg, opt))(state, batch)
        # 4x2 mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        with use_mesh(mesh):
            state2 = init_train_state(jax.random.PRNGKey(0), cfg, opt)
            st_sh = shardings(state_pspecs(jax.eval_shape(lambda: state2),
                                           mesh), mesh)
            step = jax.jit(make_train_step(cfg, opt))
            _, m2 = step(state2, batch)
        print(json.dumps({"l1": float(m1["loss"]), "l2": float(m2["loss"])}))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert abs(r["l1"] - r["l2"]) < 1e-2, r


def test_elastic_checkpoint_restore_smaller_mesh(tmp_path):
    out = _run(f"""
        from repro.configs import get_config
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        from repro.launch.mesh import make_mesh
        from repro.optim import adamw, make_schedule
        from repro.sharding import use_mesh
        from repro.train import init_train_state, make_train_step
        from repro.data import model_batch
        cfg = get_config("qwen2_0_5b", smoke=True)
        opt = adamw(make_schedule("constant", peak=1e-3))
        mesh8 = make_mesh((4, 2), ("data", "model"))
        batch = {{k: jnp.asarray(v) for k, v in
                 model_batch(cfg, 8, 16).items()}}
        with use_mesh(mesh8):
            state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
            state, m = jax.jit(make_train_step(cfg, opt))(state, batch)
            save_checkpoint("{tmp_path}", 1, state)
        # restore on a smaller (2,2) mesh — degraded pod
        mesh4 = make_mesh((2, 2), ("data", "model"))
        with use_mesh(mesh4):
            template = jax.tree.map(np.asarray,
                                    init_train_state(jax.random.PRNGKey(0),
                                                     cfg, opt))
            step, host = restore_checkpoint("{tmp_path}", template)
            state2 = jax.tree.map(jnp.asarray, host)
            state2, m2 = jax.jit(make_train_step(cfg, opt))(state2, batch)
        print(json.dumps({{"step": step, "loss": float(m2["loss"])}}))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["step"] == 1 and r["loss"] > 0


def test_moe_shard_map_vs_local():
    out = _run("""
        from repro.configs import get_config
        from repro.models import init_params, forward
        from repro.launch.mesh import make_mesh
        from repro.sharding import use_mesh
        cfg = get_config("dbrx_132b", smoke=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.arange(32).reshape(2, 16) % cfg.vocab_size}
        l1, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
        mesh = make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            l2, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
        err = float(jnp.max(jnp.abs(l1 - l2)))
        print(json.dumps({"err": err}))
    """)
    r = json.loads(out.strip().splitlines()[-1])
    assert r["err"] < 1e-3, r
