"""End-to-end training driver example with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py [--arch granite_3_2b]
                                               [--steps 200] [--full]

Runs the fault-tolerant train loop (repro.launch.train) on a smoke config
by default; ``--full`` uses the real architecture config (needs
accelerators). Demonstrates: WSD/cosine schedules, checkpointing, resume,
and the straggler watchdog. A mid-run SIGINT can be resumed with the same
command (resume=auto).
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    print(f"training {cfg.name} ({cfg.param_counts()['total']/1e6:.1f}M "
          f"params) for {args.steps} steps")
    state, hist = train_loop(
        cfg, steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt, ckpt_every=50, resume="auto", lr=3e-3)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    print(f"loss: {first:.4f} -> {last:.4f}")


if __name__ == "__main__":
    main()
