"""Serving example: batched prefill + decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen2_0_5b]
                                               [--tokens 32]

Prefills a batch of prompts, then decodes greedily token by token —
exactly the ops the decode_* dry-run shapes lower at pod scale.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import model_batch
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    S_max = args.prompt_len + args.tokens

    batch = {k: jnp.asarray(v) for k, v in
             model_batch(cfg, args.batch, args.prompt_len).items()}

    pre = jax.jit(lambda p, b: prefill(p, b, cfg, S_max))
    dec = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))

    t0 = time.time()
    logits, cache = pre(params, batch)
    print(f"prefill B={args.batch} S={args.prompt_len}: "
          f"{time.time()-t0:.2f}s (incl. compile)")

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, cache = dec(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    seq = jnp.concatenate(out, 1)
    print(f"decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s incl. 1st compile)")
    print("sampled ids[0]:", seq[0][:16].tolist())

    # --- continuous batching: more requests than slots, mixed sampling ---
    import numpy as np

    from repro.serve import Engine, Request
    eng = Engine(params, cfg, slots=args.batch, s_max=S_max)
    n_req = args.batch * 2
    for i in range(n_req):
        eng.submit(Request(uid=i, tokens=np.arange(4 + i) % cfg.vocab_size,
                           max_new=args.tokens // 2,
                           temperature=0.7 if i % 2 else 0.0, top_k=40))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in done)
    print(f"\nengine: {n_req} requests through {args.batch} slots -> "
          f"{total} tokens in {dt:.2f}s "
          f"(mean TTFT {1e3*np.mean([r.t_first - r.t_submit for r in done]):.0f}ms)")


if __name__ == "__main__":
    main()
