"""Framework integration example: k-center coreset data curation.

    PYTHONPATH=src python examples/coreset_curation.py

Embeds a pool of synthetic sequences with a small LM (mean-pooled hidden
states), selects a maximally-diverse k-subset with the paper's MRG, and
compares training on the curated subset vs a random subset of equal size.
This is the production use-case wiring (DESIGN.md §3): the clustering runs
on the same device (mesh) as training.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import select_coreset
from repro.data import model_batch
from repro.models import forward, init_params
from repro.optim import adamw, make_schedule
from repro.train import init_train_state, make_train_step


def embed_pool(params, cfg, pool_tokens):
    """Mean-pooled final hidden state per example."""
    outs = []
    fwd = jax.jit(lambda p, t: forward(p, {"tokens": t}, cfg,
                                       return_hidden=True)[0])
    for i in range(0, pool_tokens.shape[0], 64):
        h = fwd(params, pool_tokens[i : i + 64])
        outs.append(jnp.mean(h.astype(jnp.float32), axis=1))
    return jnp.concatenate(outs, 0)


def train_on(tokens, labels, cfg, steps=25, seed=0):
    opt = adamw(make_schedule("cosine", peak=5e-3, warmup=3, total=steps))
    state = init_train_state(jax.random.PRNGKey(seed), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt))
    B = 16
    losses = []
    for s in range(steps):
        idx = np.random.default_rng(s).integers(0, tokens.shape[0], B)
        batch = {"tokens": tokens[idx], "labels": labels[idx]}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return float(np.mean(losses[-5:]))


def main():
    cfg = get_config("qwen2_0_5b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)

    # pool of 1024 examples from two very different synthetic "domains"
    a = model_batch(cfg, 512, 32, seed=1)
    b = model_batch(cfg, 512, 32, seed=2)
    pool_t = jnp.concatenate([jnp.asarray(a["tokens"]),
                              jnp.asarray(b["tokens"])])
    pool_l = jnp.concatenate([jnp.asarray(a["labels"]),
                              jnp.asarray(b["labels"])])

    t0 = time.time()
    emb = embed_pool(params, cfg, pool_t)
    print(f"embedded pool {emb.shape} in {time.time()-t0:.1f}s")

    k = 256
    t0 = time.time()
    cs = select_coreset(emb, k)
    print(f"k-center coreset: k={k}, covering radius "
          f"{float(jnp.sqrt(cs.radius2)):.3f}, "
          f"weights sum={int(cs.weights.sum())}, "
          f"{time.time()-t0:.1f}s")

    cur_loss = train_on(pool_t[cs.indices], pool_l[cs.indices], cfg)
    rnd_idx = np.random.default_rng(0).choice(pool_t.shape[0], k,
                                              replace=False)
    rnd_loss = train_on(pool_t[rnd_idx], pool_l[rnd_idx], cfg)
    print(f"\nfinal train loss — coreset: {cur_loss:.4f}  "
          f"random: {rnd_loss:.4f}")
    print("(coreset covers both domains by construction; random may not)")


if __name__ == "__main__":
    main()
