"""Data curation by diversity on the source × executor substrate.

    PYTHONPATH=src python examples/coreset_curation.py [--n N]

Generates an out-of-core GAU "embedding cloud" (``synthetic_source`` —
blocks are regenerated on demand, never stored), selects a maximally-
diverse k-subset with the streamed MRG (``select_coreset`` on a
``HostStreamExecutor``), and compares its covering radius against a
random subset of equal size — the curation claim in one number: every
pool example sits close to some curated example, which no random subset
of planted-cluster data guarantees. A second pass re-runs the selection
on a ``WeightedSource`` (weights = per-row importance) and a
``kz_center`` pass shows the outlier-aware variant ignoring a planted
contamination cluster. No step materializes the pool.
"""
import argparse
import time

import numpy as np

from repro.core import HostStreamExecutor, kz_center, select_coreset
from repro.core.outliers import covering_radius_excluding
from repro.data import HostSource, WeightedSource, synthetic_source


def main(n: int = 50_000) -> None:
    k = 128
    rows = -(-n // 50)
    ex = HostStreamExecutor(block_rows=rows)
    pool = synthetic_source("gau", n, d=8, k_prime=25, seed=0)
    print(f"pool: streamed GAU embedding cloud n={n}, d=8, "
          f"25 planted clusters; k={k}\n")

    t0 = time.time()
    cs = select_coreset(pool, k, executor=ex)
    cur_r = float(np.sqrt(np.asarray(cs.radius2)))
    print(f"coreset  curated   covering radius={cur_r:8.4f}  "
          f"wall={time.time()-t0:6.2f}s  "
          f"(weights sum={int(np.asarray(cs.weights).sum())})")
    assert int(np.asarray(cs.weights).sum()) == n

    # random subset of equal size, scored by the same streamed fold
    rng = np.random.default_rng(0)
    rand = np.asarray(pool.take(rng.choice(n, k, replace=False)))
    rnd_r = float(covering_radius_excluding(pool, rand, 0,
                                            block_rows=rows))
    print(f"random   baseline  covering radius={rnd_r:8.4f}  "
          f"(same streamed top-1 fold)")
    assert cur_r <= rnd_r + 1e-6, (cur_r, rnd_r)

    # weighted pool: importance weights ride the same streamed rounds
    w = (rng.random(n).astype(np.float32) * 4.0 + 1.0)
    t0 = time.time()
    wcs = select_coreset(WeightedSource(pool, w), k, executor=ex)
    print(f"weighted coreset   covering radius="
          f"{float(np.sqrt(np.asarray(wcs.radius2))):8.4f}  "
          f"wall={time.time()-t0:6.2f}s  "
          f"(importance mass={float(np.asarray(wcs.weights).sum()):.1f})")
    assert abs(float(np.asarray(wcs.weights).sum()) - float(w.sum())) \
        <= 1e-3 * float(w.sum())

    # outlier-aware: contaminate 0.2% of the pool far away; kz_center's
    # weighted-coreset + host solve excludes it, plain curation cannot
    z = max(n // 500, 1)
    x = np.asarray(pool.take(np.arange(n)), np.float32).copy()
    x[:z] += 500.0
    t0 = time.time()
    res = kz_center(HostSource(x), k, z, executor=ex)
    kz_r = float(np.sqrt(np.asarray(res.radius2)))
    print(f"kz_center outliers z={z}  radius={kz_r:8.4f}  "
          f"wall={time.time()-t0:6.2f}s  "
          f"(coreset={res.coreset_size}, rounds={res.rounds})")
    assert kz_r < 400.0          # the contamination was excluded

    print("\ncurated ≤ random by construction (k-center maximizes "
          "diversity); the\noutlier run ignores the planted contamination "
          "— all passes streamed.")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="streamed k-center data curation (+weights, +outliers)")
    ap.add_argument("--n", type=int, default=50_000,
                    help="pool size (default 50k)")
    main(ap.parse_args().n)
