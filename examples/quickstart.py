"""Quickstart: the paper's three k-center algorithms on clustered data.

    PYTHONPATH=src python examples/quickstart.py

Generates a GAU point set (25 planted clusters, paper §7.3), runs
GON / MRG / EIM, and prints covering radii + timings — a miniature of the
paper's Tables 2-4 experiment.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import eim, gonzalez, mrg_sim
from repro.data import gau


def main():
    n, k_prime, k = 100_000, 25, 25
    pts = jnp.asarray(gau(n, k_prime, seed=0))
    print(f"GAU data: n={n}, planted clusters={k_prime}, k={k}\n")

    t0 = time.time()
    g = gonzalez(pts, k)
    g_r = float(jnp.sqrt(g.radius2))
    print(f"GON  (2-approx, sequential)      radius={g_r:8.4f}  "
          f"wall={time.time()-t0:6.2f}s")

    t0 = time.time()
    m = mrg_sim(pts, k, m=50)
    m_r = float(jnp.sqrt(m.radius2))
    print(f"MRG  (4-approx, {m.rounds} rounds, m=50)  radius={m_r:8.4f}  "
          f"wall={time.time()-t0:6.2f}s (simulated machines)")

    t0 = time.time()
    e = eim(pts, k, jax.random.PRNGKey(0), eps=0.1, phi=8.0)
    e_r = float(jnp.sqrt(e.radius2))
    print(f"EIM  (10-approx w.s.p., φ=8)     radius={e_r:8.4f}  "
          f"wall={time.time()-t0:6.2f}s "
          f"(iters={int(e.sample.iters)}, "
          f"sample={int(e.sample.sample_mask.sum())})")

    print("\nWith k = k', all three should find the planted clusters "
          "(radius ≈ cluster σ-scale, paper Table 2's k=25 row).")
    assert m_r <= 4 * g_r and e_r <= 10 * g_r


if __name__ == "__main__":
    main()
