"""Quickstart: the paper's three k-center algorithms on clustered data,
on the source × executor substrate.

    PYTHONPATH=src python examples/quickstart.py [--n N]

Generates a GAU point set (25 planted clusters, paper §7.3), runs
GON / MRG / EIM three ways — in memory, out-of-core (``HostSource`` on a
``HostStreamExecutor``), and sharded (``shard_source`` on a streamed
``MeshExecutor``: each mesh shard streams its own per-host source, no
host-side full-n pass) — and prints covering radii + timings: a miniature
of the paper's Tables 2-4 experiment plus the repo's out-of-core
contract. The streamed runs are *bitwise* the in-memory machine blocking,
which the script asserts.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import (HostStreamExecutor, MeshExecutor, eim, gonzalez,
                        mrg, mrg_sim)
from repro.data import HostSource, gau, shard_source


def main(n: int = 100_000) -> None:
    k_prime = k = 25
    x_np = np.asarray(gau(n, k_prime, seed=0), np.float32)
    pts = jnp.asarray(x_np)
    print(f"GAU data: n={n}, planted clusters={k_prime}, k={k}\n")

    t0 = time.time()
    g = gonzalez(pts, k)
    g_r = float(jnp.sqrt(g.radius2))
    print(f"GON  (2-approx, sequential)      radius={g_r:8.4f}  "
          f"wall={time.time()-t0:6.2f}s")

    m = 50
    t0 = time.time()
    res_sim = mrg_sim(pts, k, m=m)
    m_r = float(jnp.sqrt(res_sim.radius2))
    # Lemma 3: 2 rounds give 4-approx, +2 per extra combine level (small
    # --n forces extra levels because the k·m union outgrows ceil(n/m)).
    m_approx = 2 * res_sim.rounds
    print(f"MRG  ({m_approx}-approx, {res_sim.rounds} rounds, m={m})  "
          f"radius={m_r:8.4f}  wall={time.time()-t0:6.2f}s "
          f"(simulated machines)")

    t0 = time.time()
    e = eim(pts, k, jax.random.PRNGKey(0), eps=0.1, phi=8.0)
    e_r = float(jnp.sqrt(e.radius2))
    print(f"EIM  (10-approx w.s.p., φ=8)     radius={e_r:8.4f}  "
          f"wall={time.time()-t0:6.2f}s "
          f"(iters={int(e.sample.iters)}, "
          f"sample={int(np.asarray(e.sample.sample_mask).sum())})")

    # --- out-of-core: same machine blocking as mrg_sim, streamed ---------
    per = -(-n // m)
    ex = HostStreamExecutor(block_rows=per)
    t0 = time.time()
    res_ooc = mrg(HostSource(x_np), k, executor=ex)
    print(f"MRG  out-of-core (HostSource)    "
          f"radius={float(jnp.sqrt(res_ooc.radius2)):8.4f}  "
          f"wall={time.time()-t0:6.2f}s "
          f"(super-shards of {per} rows, bitwise the m={m} blocking)")
    assert np.array_equal(np.asarray(res_ooc.centers),
                          np.asarray(res_sim.centers))
    assert float(res_ooc.radius2) == float(res_sim.radius2)

    # --- sharded: the paper's machine model — input partitioned across
    # machines, each mesh shard streaming its own source ------------------
    mesh = compat.make_mesh(np.array(jax.devices()[:1]), ("data",))
    mex = MeshExecutor(mesh, block_rows=per)
    t0 = time.time()
    res_sh = mrg(shard_source(HostSource(x_np), mesh), k, executor=mex)
    print(f"MRG  sharded (MeshExecutor)      "
          f"radius={float(jnp.sqrt(res_sh.radius2)):8.4f}  "
          f"wall={time.time()-t0:6.2f}s "
          f"({mex.num_shards} mesh shard(s), per-shard streams)")
    assert float(res_sh.radius2) == float(res_ooc.radius2)

    print("\nWith k = k', all three algorithms should find the planted "
          "clusters\n(radius ≈ cluster σ-scale, paper Table 2's k=25 row); "
          "streamed runs are\nbitwise their in-memory machine blocking.")
    assert m_r <= m_approx * g_r and e_r <= 10 * g_r


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="k-center quickstart (GON / MRG / EIM, three substrates)")
    ap.add_argument("--n", type=int, default=100_000,
                    help="points to generate (default 100k)")
    main(ap.parse_args().n)
