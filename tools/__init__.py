"""Repo-local developer tooling (no runtime dependency on ``src/``)."""
