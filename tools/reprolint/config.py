"""Repo-tuned scoping: rule scopes, whitelists, oracles, jitted callees.

Everything path-like is a posix-style path relative to the repo root.
Keeping the tuning here (rather than inside the rules) makes each rule a
pure pattern matcher and leaves one auditable place that says *where*
each contract is binding and *who* is exempt, and why.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

# -- rule scopes ------------------------------------------------------------
# rule id -> path prefixes (or exact files) the rule is binding in.
# None means "everywhere the CLI is pointed at".
SCOPES: Dict[str, Optional[Tuple[str, ...]]] = {
    # Version-drifting jax APIs must route through repro.compat — binding
    # repo-wide; compat.py itself is the whitelisted implementation site.
    "R001": None,
    # The MapReduce memory model is a contract of the algorithm/data
    # layers; examples and benchmarks may deliberately materialize small
    # references (they print and compare against oracles).
    "R002": ("src/repro/core/", "src/repro/data/"),
    # Blocking-invariant sampling: binding on the streamed algorithm
    # paths and the sampler engine itself. serve/ and models/ draw from
    # jax.random by design (per-request sampling is not blocked data).
    "R003": ("src/repro/core/", "src/repro/kernels/engine.py"),
    # Recompile hazards matter wherever ragged block streams meet jitted
    # callees.
    "R004": ("src/repro/", "benchmarks/", "examples/"),
    # Philox limb arithmetic lives in exactly one module.
    "R005": ("src/repro/kernels/engine.py",),
}

# -- whole-file whitelists --------------------------------------------------
# rule id -> exact relpaths exempt from that rule.
WHITELIST: Dict[str, Tuple[str, ...]] = {
    # compat.py is the one sanctioned home of the drifting symbols.
    "R001": ("src/repro/compat.py",),
}

# Files reprolint skips entirely (generated/vendored — none today).
SKIP_FILES: Tuple[str, ...] = ()

# -- R002: declared oracle functions ---------------------------------------
# Functions allowed to touch all n rows. Any function *named*
# ``materialize`` is an oracle by definition (it IS the sanctioned
# escape hatch of the PointSource protocol). Beyond that, whole
# functions are listed here — (relpath, qualname) -> justification —
# when materializing is their documented job; one-line device-path
# branches inside otherwise-streamed functions use inline suppressions
# instead, so the exemption stays exactly as wide as the contract.
ORACLES: Dict[Tuple[str, str], str] = {
    ("src/repro/core/executor.py", "SimExecutor.run_blocks"):
        "SimExecutor simulates m machines on one device: materialize + "
        "block is its documented semantics (ARCHITECTURE.md, Executors).",
    ("src/repro/core/executor.py", "SimExecutor._blocked_for"):
        "the weakref-cached materialize+block behind SimExecutor's EIM "
        "filter rounds — same contract as run_blocks.",
    ("src/repro/core/executor.py", "MeshExecutor._mrg_fused"):
        "the fused single-dispatch MRG path shards a device-resident "
        "copy across the mesh; whole-array residency is its premise "
        "(tested for parity against the streamed path).",
}

ORACLE_NAMES: Tuple[str, ...] = ("materialize",)

# Names that look like whole-source bindings for the asarray pattern.
SOURCE_NAMES: Tuple[str, ...] = ("source", "src")
SOURCE_SUFFIXES: Tuple[str, ...] = ("_source", "_src")

# -- R003: jax.random key management (allowed) vs draws (forbidden) --------
KEY_OPS: Tuple[str, ...] = (
    "PRNGKey", "key", "split", "fold_in", "key_data", "wrap_key_data",
    "clone", "key_impl", "default_prng_impl", "KeyArray",
)

# -- R004: block-stream producers and known-jitted callees -----------------
# Iterating these produces ragged (tail-short) blocks. stream_device /
# zip_shard_blocks / _stream_steps are deliberately absent: they yield
# pre-padded fixed-shape steps (that is their whole point).
RAGGED_STREAMS: Tuple[str, ...] = (
    "blocks", "host_blocks", "_blocks", "_source_blocks",
)

# Callees known to be jitted but defined in another module (module-local
# jit decorations/wrappings are auto-detected by the rule). The fused
# streamed-tile entry points (kernels/fused_stream.py + engine.py) are
# jitted on (rank/bn/interpret)-static signatures: feeding them raw ragged
# tail blocks would recompile per tail shape, so R004 demands the
# pad-to-fixed-rows dance wherever a ragged stream reaches them.
JITTED_CALLEES: Tuple[str, ...] = (
    "bernoulli_rows_block", "bernoulli_rows_at_block",
    "eim_filter_block", "_eim_filter_block",
    "fused_filter_blocks", "fused_assign_blocks", "fused_argmin_blocks",
    # The weighted sibling of fused_filter_blocks (one extra (bn,) weight
    # operand, same (rank/bn/interpret)-static jit signature): the same
    # ragged-tail recompile hazard, so the same pad-dance obligation.
    "fused_filter_blocks_w",
    # The serving query entry point (kernels/engine.py): eager rather than
    # jitted, but shape-signature-sensitive all the same — its recompile
    # discipline rests on callers padding to the fixed (query-bucket,
    # center-bucket) shapes, so ragged streams must do the pad dance
    # before reaching it (serve/kcenter.py does).
    "assign_bucketed",
)

# Call names that sanitize a ragged block (pad-to-``rows`` family).
PAD_CALLS: Tuple[str, ...] = ("pad",)

# -- R005: Philox helper selection -----------------------------------------
# Function names whose bodies must stay pure uint32. The host-side
# splitters (uniform_rows's start>>32, split_index_words's np.uint64)
# are deliberately OUT of scope: they run in Python/NumPy on the host
# before anything reaches the device, where x64 is always available.
PHILOX_FUNC_PREFIXES: Tuple[str, ...] = (
    "_philox", "_mulhilo", "_uniform_rows_words", "_uniform_at_words",
)

WIDE_DTYPES: Tuple[str, ...] = ("int64", "uint64", "float64")


def in_scope(rule_id: str, relpath: str) -> bool:
    if relpath in SKIP_FILES:
        return False
    scope = SCOPES.get(rule_id)
    if scope is None:
        return True
    return any(
        relpath == s or (s.endswith("/") and relpath.startswith(s))
        for s in scope
    )


def file_whitelisted(relpath: str) -> bool:
    return relpath in SKIP_FILES


def rule_whitelisted(rule_id: str, relpath: str) -> bool:
    return relpath in WHITELIST.get(rule_id, ())


def is_source_name(name: str) -> bool:
    return name in SOURCE_NAMES or name.endswith(SOURCE_SUFFIXES)


def oracle_justification(relpath: str, qualname: str) -> Optional[str]:
    return ORACLES.get((relpath, qualname))
