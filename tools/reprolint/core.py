"""Rule framework: registry, diagnostics, suppressions, file walking.

Design constraints (ISSUE 6):

* pure stdlib — no jax import anywhere in ``tools.reprolint``, so the
  checker runs identically on both CI jax lines (and on a bare runner
  with no jax at all);
* per-line ``# reprolint: disable=RULE -- justification`` suppressions
  with *mandatory* justification text — a suppression without one is
  itself an error (R000) and does not silence anything;
* per-directory/file whitelists live in :mod:`tools.reprolint.config`,
  rule scoping is by posix-style path prefix.

Suppression grammar (one physical line)::

    <code>  # reprolint: disable=R002 -- device path needs random access
    # reprolint: disable=R002,R004 -- <why>        (standalone: applies
    <code>                                          to the next line)

The justification is everything after ``--`` and must be at least
MIN_JUSTIFICATION characters of real text.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Set, Tuple

MIN_JUSTIFICATION = 10

# ids must be RNNN-shaped: prose that merely *mentions* the directive
# syntax ("disable=RULE ...") is not a directive.
_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*disable=([A-Z]\d{3}(?:\s*,\s*[A-Z]\d{3})*)"
    r"(?:\s+--\s*(?P<why>.*\S))?\s*$"
)


@dataclass(frozen=True, order=True)
class Diagnostic:
    """One finding: ``path:line rule message``."""

    path: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"


class Rule:
    """Base class for reprolint rules.

    Subclasses set ``id``/``name`` and implement :meth:`check`, yielding
    :class:`Diagnostic` objects anchored at the offending node's line.
    The class docstring is the rule's contract statement — it must name
    the invariant enforced and the test / ARCHITECTURE section that pins
    it (rendered by ``--list-rules``).
    """

    id: str = ""
    name: str = ""

    def applies_to(self, relpath: str) -> bool:
        from . import config

        return config.in_scope(self.id, relpath)

    def check(self, tree: ast.AST, text: str, relpath: str) -> Iterator[Diagnostic]:
        raise NotImplementedError

    # -- helpers shared by rules ---------------------------------------

    @staticmethod
    def dotted(node: ast.AST) -> Optional[str]:
        """Resolve a Name/Attribute chain to ``"a.b.c"`` (else None)."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
            return ".".join(reversed(parts))
        return None

    @staticmethod
    def terminal(node: ast.AST) -> Optional[str]:
        """Last component of a call target: ``a.b.c`` -> ``c``."""
        if isinstance(node, ast.Attribute):
            return node.attr
        if isinstance(node, ast.Name):
            return node.id
        return None


REGISTRY: Dict[str, Rule] = {}


def register(cls: type) -> type:
    """Class decorator: instantiate and register a rule by its id."""
    rule = cls()
    if not rule.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if rule.id in REGISTRY:
        raise ValueError(f"duplicate rule id {rule.id}")
    REGISTRY[rule.id] = rule
    return cls


def all_rules() -> List[Rule]:
    return [REGISTRY[k] for k in sorted(REGISTRY)]


# ---------------------------------------------------------------------------
# suppressions


@dataclass
class _Suppression:
    line: int           # line the directive is written on
    applies_to: int     # line it silences
    rules: Set[str] = field(default_factory=set)
    justified: bool = False
    used: bool = False


def _parse_suppressions(text: str) -> Tuple[List[_Suppression], List[Diagnostic]]:
    sups: List[_Suppression] = []
    errors: List[Diagnostic] = []
    lines = text.splitlines()
    for i, raw in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(raw)
        if not m:
            continue
        ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
        why = m.group("why") or ""
        justified = len(why.strip()) >= MIN_JUSTIFICATION
        # standalone comment line -> applies to the next line
        target = i + 1 if raw.lstrip().startswith("#") else i
        sups.append(_Suppression(i, target, ids, justified))
    return sups, errors


def _apply_suppressions(
    diags: List[Diagnostic], sups: List[_Suppression], relpath: str
) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    known = set(REGISTRY)
    for d in diags:
        silenced = False
        for s in sups:
            if s.applies_to == d.line and d.rule in s.rules and s.justified:
                s.used = True
                silenced = True
                break
        if not silenced:
            out.append(d)
    for s in sups:
        if not s.justified:
            out.append(Diagnostic(
                relpath, s.line, "R000",
                "suppression without justification — write "
                "`# reprolint: disable=RXXX -- <why, at least "
                f"{MIN_JUSTIFICATION} chars>`"))
        unknown = s.rules - known
        for rid in sorted(unknown):
            out.append(Diagnostic(
                relpath, s.line, "R000", f"unknown rule id {rid!r} in suppression"))
    return out


# ---------------------------------------------------------------------------
# entry points


def check_source(
    text: str,
    relpath: str,
    rules: Optional[Iterable[Rule]] = None,
) -> List[Diagnostic]:
    """Lint one source string as if it lived at ``relpath`` (posix).

    ``relpath`` drives rule scoping and whitelists, so fixture tests can
    place snippets at virtual paths like ``src/repro/core/x.py``.
    """
    relpath = relpath.replace("\\", "/")
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Diagnostic(relpath, e.lineno or 1, "E999",
                           f"syntax error: {e.msg}")]
    from . import config

    if config.file_whitelisted(relpath):
        return []
    active = [r for r in (rules or all_rules()) if r.applies_to(relpath)]
    diags: List[Diagnostic] = []
    for rule in active:
        diags.extend(rule.check(tree, text, relpath))
    sups, errs = _parse_suppressions(text)
    diags.extend(errs)
    return sorted(_apply_suppressions(diags, sups, relpath))


def check_file(path: Path, root: Optional[Path] = None) -> List[Diagnostic]:
    root = root or Path.cwd()
    try:
        rel = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    return check_source(path.read_text(encoding="utf-8"), rel)


def iter_python_files(paths: Iterable[str], root: Path) -> Iterator[Path]:
    for p in paths:
        pp = (root / p) if not Path(p).is_absolute() else Path(p)
        if pp.is_file():
            yield pp
        elif pp.is_dir():
            for f in sorted(pp.rglob("*.py")):
                if "__pycache__" in f.parts:
                    continue
                yield f


def run_paths(paths: Iterable[str], root: Optional[Path] = None) -> List[Diagnostic]:
    root = root or Path.cwd()
    diags: List[Diagnostic] = []
    for f in iter_python_files(paths, root):
        diags.extend(check_file(f, root))
    return sorted(diags)
