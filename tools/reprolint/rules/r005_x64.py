"""R005 x64-hygiene.

Contract: the Philox-4x32-10 limb arithmetic in
``src/repro/kernels/engine.py`` (``_mulhilo32``, ``_philox_rows``,
``_uniform_rows_words``, ``_uniform_at_words``) stays pure uint32 — no
``int64``/``uint64``/``float64`` dtype references and no shifts by >= 32
bits. With ``JAX_ENABLE_X64=0`` (the repo default and the CI
determinism job), a 64-bit op would be silently truncated to 32 bits
and the sampled bits would differ from the x64-on run, breaking the
bitwise determinism pin. Counter splitting that genuinely needs 64-bit
row indices happens on the *host* (``uniform_rows``'s ``start >> 32``,
``split_index_words``) before anything reaches the device — those are
deliberately out of scope (see config.PHILOX_FUNC_PREFIXES).

Pinned by: the CI determinism job (JAX_ENABLE_X64=0 grid of
tests/test_eim_stream.py) and ARCHITECTURE.md "Engine" (Philox
paragraph).
"""
from __future__ import annotations

import ast
from typing import Iterator, List

from .. import config
from ..core import Diagnostic, Rule, register


def _philox_scoped(name: str) -> bool:
    return name.startswith(config.PHILOX_FUNC_PREFIXES)


@register
class X64Hygiene(Rule):
    __doc__ = __doc__

    id = "R005"
    name = "x64-hygiene"

    def check(self, tree: ast.AST, text: str, relpath: str) -> Iterator[Diagnostic]:
        diags: List[Diagnostic] = []

        def scan(func: ast.FunctionDef) -> None:
            for node in ast.walk(func):
                if (isinstance(node, ast.Attribute)
                        and node.attr in config.WIDE_DTYPES):
                    diags.append(Diagnostic(
                        relpath, node.lineno, "R005",
                        f"{node.attr} inside Philox helper "
                        f"{func.name}(); limb arithmetic must stay pure "
                        "uint32 (x64-off truncates silently)"))
                elif (isinstance(node, ast.Name)
                        and node.id in config.WIDE_DTYPES):
                    diags.append(Diagnostic(
                        relpath, node.lineno, "R005",
                        f"{node.id} inside Philox helper {func.name}()"))
                elif (isinstance(node, ast.BinOp)
                        and isinstance(node.op, (ast.LShift, ast.RShift))):
                    for side in (node.left, node.right):
                        if (isinstance(side, ast.Constant)
                                and isinstance(side.value, int)
                                and side.value >= 32):
                            diags.append(Diagnostic(
                                relpath, node.lineno, "R005",
                                f"shift by {side.value} inside Philox "
                                f"helper {func.name}(); limbs are 32-bit"))

        for node in ast.walk(tree):
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and _philox_scoped(node.name)):
                scan(node)

        yield from diags
