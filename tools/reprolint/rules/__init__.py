"""Rule modules — importing this package registers every rule."""
from . import (  # noqa: F401
    r001_compat,
    r002_full_n,
    r003_sampler,
    r004_recompile,
    r005_x64,
)
