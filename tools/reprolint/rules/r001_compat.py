"""R001 compat-only-imports.

Contract: version-drifting jax APIs (``jax.experimental.shard_map``,
top-level ``jax.shard_map``, ``jax.set_mesh``,
``jax.make_array_from_single_device_arrays``, ``jax.sharding.AxisType``,
``jax.experimental.multihost_utils``, ``jax.distributed``
initialize/shutdown) are used *only* inside ``src/repro/compat.py`` — every other module goes
through the feature-detected shim so the tree imports and runs on both
the jax 0.4.x and 0.6+ CI lines.

Pinned by: ARCHITECTURE.md "Version portability" and
``tests/test_compat_fallbacks.py`` (the shim's legacy branches);
the whitelist is ``config.WHITELIST["R001"]``.
"""
from __future__ import annotations

import ast
from typing import Iterator, List

from .. import config
from ..core import Diagnostic, Rule, register

_FORBIDDEN_MODULES = (
    "jax.experimental.shard_map",
    "jax.experimental.multihost_utils",
)

_FORBIDDEN_FROM = {
    ("jax", "shard_map"),
    ("jax", "set_mesh"),
    ("jax", "make_array_from_single_device_arrays"),
    ("jax.sharding", "AxisType"),
    ("jax.experimental", "shard_map"),
    ("jax.experimental", "multihost_utils"),
    ("jax.distributed", "initialize"),
    ("jax.distributed", "shutdown"),
}

_FORBIDDEN_ATTRS = {
    "jax.shard_map",
    "jax.set_mesh",
    "jax.make_array_from_single_device_arrays",
    "jax.sharding.AxisType",
    "jax.experimental.shard_map",
    "jax.experimental.multihost_utils",
    "jax.distributed.initialize",
    "jax.distributed.shutdown",
}

_HINT = "route it through repro.compat (extend the shim if missing)"


@register
class CompatOnlyImports(Rule):
    __doc__ = __doc__

    id = "R001"
    name = "compat-only-imports"

    def check(self, tree: ast.AST, text: str, relpath: str) -> Iterator[Diagnostic]:
        if config.rule_whitelisted(self.id, relpath):
            return
        diags: List[Diagnostic] = []

        class V(ast.NodeVisitor):
            def visit_Import(self, node: ast.Import) -> None:
                for alias in node.names:
                    for mod in _FORBIDDEN_MODULES:
                        if alias.name == mod or alias.name.startswith(mod + "."):
                            diags.append(Diagnostic(
                                relpath, node.lineno, "R001",
                                f"direct import of {alias.name!r}; {_HINT}"))

            def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
                mod = node.module or ""
                if mod in _FORBIDDEN_MODULES:
                    diags.append(Diagnostic(
                        relpath, node.lineno, "R001",
                        f"direct import from {mod!r}; {_HINT}"))
                    return
                for alias in node.names:
                    if (mod, alias.name) in _FORBIDDEN_FROM:
                        diags.append(Diagnostic(
                            relpath, node.lineno, "R001",
                            f"direct import of {mod}.{alias.name}; {_HINT}"))

            def visit_Attribute(self, node: ast.Attribute) -> None:
                dn = Rule.dotted(node)
                if dn is not None and (
                    dn in _FORBIDDEN_ATTRS
                    or any(dn.startswith(a + ".") for a in _FORBIDDEN_ATTRS)
                ):
                    diags.append(Diagnostic(
                        relpath, node.lineno, "R001",
                        f"direct use of drifting jax API {dn!r}; {_HINT}"))
                    return  # don't recurse: avoid re-flagging the prefix
                self.generic_visit(node)

        V().visit(tree)
        yield from diags
