"""R002 no-full-n.

Contract: the MapReduce memory model (paper §2; ARCHITECTURE.md
"Compacted-R iteration") — no code path in ``core/`` or ``data/``
materializes all n rows outside declared oracle functions. Device
residency on streamed paths is bounded by ``(1+prefetch)·4·rows·(d+1)``
bytes; one careless ``source.materialize()`` / ``asarray(source)`` /
``take(arange(source.n))`` silently voids every out-of-core guarantee.

Flagged patterns:
  (a) any ``.materialize()`` call,
  (b) ``np.asarray``/``jnp.asarray`` of a source-named binding,
  (c) ``concatenate``/``stack``-family calls over a ``.blocks()`` /
      ``.host_blocks()`` stream,
  (d) ``.take(...)`` whose index expression is an ``arange`` that
      references a ``.n`` attribute (i.e. all row ids at once).

Exempt: functions named ``materialize`` (the PointSource protocol's own
escape hatch) and the whole-function oracles in ``config.ORACLES``.

Pinned by: tests/test_eim_stream.py residency pins and the
tests/test_executor.py streamed-vs-device parity grids.
"""
from __future__ import annotations

import ast
from typing import Iterator, List

from .. import config
from ..core import Diagnostic, Rule, register

_ASARRAY = {"np.asarray", "jnp.asarray", "numpy.asarray", "jax.numpy.asarray"}
_CONCAT = {"concatenate", "stack", "vstack", "hstack"}
_BLOCK_STREAMS = {"blocks", "host_blocks"}


def _contains_block_stream(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr in _BLOCK_STREAMS):
            return True
    return False


def _contains_full_arange(node: ast.AST) -> bool:
    """An ``arange(...)`` call whose arguments reference a ``.n`` attr."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and Rule.terminal(sub.func) == "arange":
            for arg in ast.walk(sub):
                if isinstance(arg, ast.Attribute) and arg.attr == "n":
                    return True
    return False


@register
class NoFullN(Rule):
    __doc__ = __doc__

    id = "R002"
    name = "no-full-n"

    def check(self, tree: ast.AST, text: str, relpath: str) -> Iterator[Diagnostic]:
        diags: List[Diagnostic] = []

        class V(ast.NodeVisitor):
            def __init__(self) -> None:
                self.stack: List[str] = []   # class/function qualname parts
                self.oracle_depth = 0

            def _qualname(self) -> str:
                return ".".join(self.stack)

            def _enter(self, node, is_func: bool) -> None:
                self.stack.append(node.name)
                oracle = False
                if is_func:
                    if node.name in config.ORACLE_NAMES:
                        oracle = True
                    elif config.oracle_justification(
                            relpath, self._qualname()) is not None:
                        oracle = True
                self.oracle_depth += oracle
                self.generic_visit(node)
                self.oracle_depth -= oracle
                self.stack.pop()

            def visit_ClassDef(self, node: ast.ClassDef) -> None:
                self._enter(node, is_func=False)

            def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
                self._enter(node, is_func=True)

            def visit_AsyncFunctionDef(self, node) -> None:
                self._enter(node, is_func=True)

            def visit_Call(self, node: ast.Call) -> None:
                if not self.oracle_depth:
                    self._check_call(node)
                self.generic_visit(node)

            def _check_call(self, node: ast.Call) -> None:
                func = node.func
                attr = func.attr if isinstance(func, ast.Attribute) else None
                if attr == "materialize":
                    diags.append(Diagnostic(
                        relpath, node.lineno, "R002",
                        "whole-source materialization outside a declared "
                        "oracle (all n rows on device)"))
                    return
                dn = Rule.dotted(func)
                if (dn in _ASARRAY and node.args
                        and isinstance(node.args[0], ast.Name)
                        and config.is_source_name(node.args[0].id)):
                    diags.append(Diagnostic(
                        relpath, node.lineno, "R002",
                        f"asarray({node.args[0].id}) materializes the whole "
                        "source; fold over blocks() instead"))
                    return
                if attr in _CONCAT and any(
                        _contains_block_stream(a) for a in node.args):
                    diags.append(Diagnostic(
                        relpath, node.lineno, "R002",
                        f"{attr}() over a block stream rebuilds all n rows; "
                        "fold block-by-block instead"))
                    return
                if attr == "take" and any(
                        _contains_full_arange(a) for a in node.args):
                    diags.append(Diagnostic(
                        relpath, node.lineno, "R002",
                        "take(arange(..n..)) gathers every row id at once"))

        V().visit(tree)
        yield from diags
