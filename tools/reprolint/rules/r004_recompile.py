"""R004 recompile-hazard.

Contract: jitted callables must see fixed operand shapes. Block streams
(``.blocks()`` / ``.host_blocks()`` / ``_source_blocks`` / executor
``_blocks``) yield a ragged tail block, so passing the raw loop block —
or its ``.shape[0]`` / ``len()`` — into a jit-compiled callee triggers
one fresh XLA compile per distinct tail shape (the exact bug class
fixed in PRs 4–5: pad the block to ``rows`` and carry a validity mask
instead). ``stream_device`` / ``zip_shard_blocks`` / ``_stream_steps``
are not flagged: they yield pre-padded fixed-shape steps by
construction.

Detection: within each ``for`` loop over a ragged stream, the loop
variable is tainted; rebinding it through a ``pad(...)`` call sanitizes
it; a tainted block (or a shape probe of one) reaching an argument of a
known-jitted callee is a hazard. Jitted callees are auto-detected from
module-local ``@jax.jit`` decorations and ``name = jax.jit(...)``
bindings, plus the cross-module set in ``config.JITTED_CALLEES``.

Pinned by: tests/test_executor.py (single-executable filter rounds) and
ARCHITECTURE.md "Compacted-R iteration" (pad-to-rows discussion).
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from .. import config
from ..core import Diagnostic, Rule, register


def _contains_dotted(node: ast.AST, dotted: str, bare: str) -> bool:
    for sub in ast.walk(node):
        dn = Rule.dotted(sub) if isinstance(sub, (ast.Attribute, ast.Name)) else None
        if dn == dotted or dn == bare:
            return True
    return False


def _module_jitted_names(tree: ast.AST) -> Set[str]:
    """Names bound (at any nesting level) to jit-compiled callables."""
    jitted: Set[str] = set(config.JITTED_CALLEES)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _contains_dotted(dec, "jax.jit", "jit"):
                    jitted.add(node.name)
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if _contains_dotted(node.value, "jax.jit", "jit"):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        jitted.add(t.id)
    return jitted


def _is_ragged_stream_iter(it: ast.AST) -> bool:
    for sub in ast.walk(it):
        if isinstance(sub, ast.Call):
            name = Rule.terminal(sub.func)
            if name in config.RAGGED_STREAMS:
                return True
    return False


def _loop_targets(target: ast.AST, it: ast.AST) -> Set[str]:
    """Names bound to the *block* by the loop target.

    ``for i, blk in enumerate(stream)`` taints only ``blk`` — the
    counter is a fixed-shape int.
    """
    if isinstance(target, ast.Name):
        return {target.id}
    if isinstance(target, ast.Tuple):
        elts = target.elts
        if (isinstance(it, ast.Call) and Rule.terminal(it.func) == "enumerate"
                and len(elts) >= 2):
            elts = elts[1:]
        out: Set[str] = set()
        for e in elts:
            out |= _loop_targets(e, it=ast.Constant(value=None))
        return out
    return set()


def _has_pad_call(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and Rule.terminal(sub.func) in config.PAD_CALLS:
            return True
    return False


def _has_ragged_use(node: ast.AST, tainted: Set[str]) -> bool:
    """A tainted Name used *as an array* (not merely its .shape/len)."""
    if isinstance(node, ast.Attribute) and node.attr == "shape":
        return False
    if isinstance(node, ast.Call) and Rule.terminal(node.func) == "len":
        return False
    if isinstance(node, ast.Name):
        return node.id in tainted
    return any(_has_ragged_use(c, tainted) for c in ast.iter_child_nodes(node))


def _has_shape_probe(node: ast.AST, tainted: Set[str]) -> bool:
    """``blk.shape[...]`` or ``len(blk)`` of a tainted name."""
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Attribute) and sub.attr == "shape"
                and isinstance(sub.value, ast.Name)
                and sub.value.id in tainted):
            return True
        if (isinstance(sub, ast.Call) and Rule.terminal(sub.func) == "len"
                and sub.args and isinstance(sub.args[0], ast.Name)
                and sub.args[0].id in tainted):
            return True
    return False


def _assign_targets(node: ast.AST) -> List[str]:
    out: List[str] = []
    targets = []
    if isinstance(node, ast.Assign):
        targets = node.targets
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        targets = [node.target]
    for t in targets:
        if isinstance(t, ast.Name):
            out.append(t.id)
        elif isinstance(t, ast.Tuple):
            out.extend(e.id for e in t.elts if isinstance(e, ast.Name))
    return out


@register
class RecompileHazard(Rule):
    __doc__ = __doc__

    id = "R004"
    name = "recompile-hazard"

    def check(self, tree: ast.AST, text: str, relpath: str) -> Iterator[Diagnostic]:
        jitted = _module_jitted_names(tree)
        diags: List[Diagnostic] = []

        for loop in ast.walk(tree):
            if not isinstance(loop, ast.For):
                continue
            if not _is_ragged_stream_iter(loop.iter):
                continue
            tainted = _loop_targets(loop.target, loop.iter)
            if not tainted:
                continue
            # lexical scan of the loop body: assignments update taint,
            # jitted calls are checked against the current taint set.
            events = []
            for stmt in loop.body:
                for sub in ast.walk(stmt):
                    if isinstance(sub, (ast.Assign, ast.AugAssign,
                                        ast.AnnAssign, ast.Call)):
                        events.append(sub)
            events.sort(key=lambda n: (n.lineno, n.col_offset))
            for ev in events:
                if isinstance(ev, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                    value = ev.value
                    if value is None:
                        continue
                    names = _assign_targets(ev)
                    if _has_pad_call(value):
                        tainted -= set(names)
                    elif _has_ragged_use(value, tainted):
                        tainted |= set(names)
                    else:
                        tainted -= set(names)
                    continue
                # ev is a Call
                callee: Optional[str] = Rule.terminal(ev.func)
                if callee not in jitted:
                    continue
                for arg in list(ev.args) + [kw.value for kw in ev.keywords]:
                    if _has_ragged_use(arg, tainted):
                        diags.append(Diagnostic(
                            relpath, ev.lineno, "R004",
                            f"ragged block passed to jitted {callee}(); "
                            "pad to `rows` (+ validity mask) first — one "
                            "compile per tail shape otherwise"))
                        break
                    if _has_shape_probe(arg, tainted):
                        diags.append(Diagnostic(
                            relpath, ev.lineno, "R004",
                            f"block shape probe passed to jitted {callee}(); "
                            "pad to `rows` and pass the fixed row count"))
                        break

        yield from diags
