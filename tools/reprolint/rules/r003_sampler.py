"""R003 sampler-key-discipline.

Contract: on the streamed algorithm paths (``core/``) and inside the
sampler engine (``kernels/engine.py``), randomness is drawn through the
counter-keyed Philox samplers (``engine.uniform_rows*`` /
``engine.bernoulli_rows*``), which key every variate by the row's
*absolute original index*. Direct ``jax.random.*`` draws are forbidden
there: a per-block ``jax.random.uniform(split(key, i), ...)`` makes the
sampled bits depend on the blocking geometry, breaking the
blocking-invariance pin (same bits for any ``block_rows``/shard split)
that every streamed-vs-device parity test relies on.

Key *management* stays allowed (``PRNGKey``/``split``/``fold_in``/
``key_data``/...): deriving per-round keys is deterministic bookkeeping,
not a draw.

Pinned by: tests/test_engine.py blocking-invariance grid and
ARCHITECTURE.md "Engine" (counter-sampler paragraph).
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Set

from .. import config
from ..core import Diagnostic, Rule, register


@register
class SamplerKeyDiscipline(Rule):
    __doc__ = __doc__

    id = "R003"
    name = "sampler-key-discipline"

    def check(self, tree: ast.AST, text: str, relpath: str) -> Iterator[Diagnostic]:
        diags: List[Diagnostic] = []
        # module aliases bound to jax.random in this file
        aliases: Set[str] = set()

        class V(ast.NodeVisitor):
            def visit_Import(self, node: ast.Import) -> None:
                for alias in node.names:
                    if alias.name == "jax.random" and alias.asname:
                        aliases.add(alias.asname)

            def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
                mod = node.module or ""
                if mod == "jax":
                    for alias in node.names:
                        if alias.name == "random":
                            aliases.add(alias.asname or "random")
                elif mod == "jax.random":
                    for alias in node.names:
                        if alias.name not in config.KEY_OPS:
                            diags.append(Diagnostic(
                                relpath, node.lineno, "R003",
                                f"direct import of jax.random.{alias.name}; "
                                "draw through the engine counter samplers "
                                "(uniform_rows*/bernoulli_rows*)"))

            def visit_Attribute(self, node: ast.Attribute) -> None:
                dn = Rule.dotted(node)
                if dn is not None:
                    draw = None
                    if dn.startswith("jax.random."):
                        draw = dn[len("jax.random."):]
                    else:
                        base, _, rest = dn.partition(".")
                        if base in aliases and rest:
                            draw = rest
                    if (draw is not None and "." not in draw
                            and draw not in config.KEY_OPS):
                        diags.append(Diagnostic(
                            relpath, node.lineno, "R003",
                            f"jax.random.{draw} draw on a streamed path; "
                            "use the engine counter samplers "
                            "(uniform_rows*/bernoulli_rows*) keyed by "
                            "absolute row index"))
                        return
                self.generic_visit(node)

        V().visit(tree)
        yield from diags
