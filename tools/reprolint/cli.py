"""CLI: ``python -m tools.reprolint src benchmarks examples``.

Exits non-zero with ``file:line rule message`` diagnostics on stdout.
``--output FILE`` additionally writes the diagnostics to a file (the CI
lint job uploads it as an artifact on failure). ``--list-rules`` prints
every registered rule with its contract docstring.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .core import all_rules, run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="repo-specific AST contract checker (stdlib-only)")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to lint (relative to cwd)")
    ap.add_argument("-o", "--output", metavar="FILE",
                    help="also write diagnostics to FILE")
    ap.add_argument("--list-rules", action="store_true",
                    help="print registered rules and their contracts")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            doc = [l.strip() for l in (rule.__doc__ or "").splitlines()]
            body = [l for l in doc if l and not l.startswith(rule.id)]
            head = body[0] if body else ""
            print(f"{rule.id} {rule.name}: {head}")
        return 0

    if not args.paths:
        ap.error("no paths given (try: src benchmarks examples)")

    diags = run_paths(args.paths, root=Path.cwd())
    lines = [d.render() for d in diags]
    for line in lines:
        print(line)
    if args.output:
        Path(args.output).write_text(
            "\n".join(lines) + ("\n" if lines else ""), encoding="utf-8")
    if diags:
        print(f"reprolint: {len(diags)} finding(s)", file=sys.stderr)
        return 1
    return 0
