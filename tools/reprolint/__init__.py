"""reprolint: repo-specific AST contract checker.

Machine-enforces the three invariants this reproduction's correctness
story rests on (see ARCHITECTURE.md "Contracts & reprolint"):

1. the MapReduce memory model — no path outside declared oracles may
   materialize all n rows (R002),
2. blocking-invariant sampling — randomness on streamed paths goes
   through the counter-keyed Philox samplers, never per-block
   ``jax.random`` draws (R003), with the limb arithmetic staying pure
   uint32 (R005),
3. version portability — drifting jax APIs route through
   ``repro.compat`` (R001), and jitted callables never see ragged block
   shapes (R004).

Pure stdlib (``ast`` + ``tokenize``-free line scanning): importable and
runnable without jax installed, so the same check runs identically on
both CI jax lines. Use as a library via :func:`check_source` /
:func:`check_file`, or as a CLI::

    python -m tools.reprolint src benchmarks examples
"""
from .core import (  # noqa: F401  (public re-exports)
    Diagnostic,
    Rule,
    all_rules,
    check_file,
    check_source,
    register,
)

__all__ = [
    "Diagnostic",
    "Rule",
    "all_rules",
    "check_file",
    "check_source",
    "register",
]

# Importing the rules package registers every rule with the registry.
from . import rules  # noqa: E402,F401
