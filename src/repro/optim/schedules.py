"""Learning-rate schedules (pure functions step -> lr).

Includes WSD (warmup-stable-decay) used by MiniCPM [arXiv:2404.06395]:
linear warmup, long stable plateau, short (typically 10%) decay tail.
"""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup: int, peak: float):
    return peak * jnp.minimum(1.0, (step + 1) / max(warmup, 1))


def cosine(step, *, peak: float, warmup: int, total: int, floor: float = 0.0):
    warm = linear_warmup(step, warmup, peak)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, cos)


def wsd(step, *, peak: float, warmup: int, total: int,
        decay_frac: float = 0.1, floor: float = 0.0):
    """Warmup-Stable-Decay (MiniCPM): plateau at peak, decay in the last
    ``decay_frac`` of training (exponential-style cosine tail)."""
    warm = linear_warmup(step, warmup, peak)
    decay_start = int(total * (1.0 - decay_frac))
    t = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
    tail = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    stable = jnp.where(step < decay_start, peak, tail)
    return jnp.where(step < warmup, warm, stable)


def constant(step, *, peak: float, warmup: int = 0, **_):
    return linear_warmup(step, warmup, peak)


SCHEDULES = {"cosine": cosine, "wsd": wsd, "constant": constant}


def make_schedule(name: str, **kw):
    fn = SCHEDULES[name]
    return lambda step: fn(step, **kw)
