from .optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    global_norm,
    make_optimizer,
)
from .schedules import SCHEDULES, constant, cosine, make_schedule, wsd  # noqa: F401
