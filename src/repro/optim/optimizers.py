"""Optimizers (pure JAX; no optax): AdamW and Adafactor.

Interface (optax-shaped, but self-contained):
  opt = adamw(lr_fn, ...) / adafactor(lr_fn, ...)
  state = opt.init(params)
  new_params, new_state = opt.update(grads, state, params)

Notes for the 1000+-node regime (DESIGN.md §6):
  * Optimizer state inherits the params' sharding (moments are tree_map'd
    images of the params), so FSDP-sharded params give FSDP-sharded state
    with no extra code.
  * Adafactor keeps factored second moments (row+col instead of full) for
    matrices — the only way the 1T-param config's state fits in
    512 × 16 GB. First moment is off by default (as in the original).
  * Weight decay is decoupled (AdamW) and applied only to >=2-D params.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(F32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    # norm in f32, but grads keep their dtype — a tree-wide f32 upcast
    # doubles live gradient memory (16 GB on the 1T-param config).
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(
        lambda g: (g.astype(F32) * scale).astype(g.dtype), grads), norm


def _decayable(p):
    return p.ndim >= 2


def adamw(lr_fn, *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, F32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr = lr_fn(step)
        b1c = 1 - b1 ** step.astype(F32)
        b2c = 1 - b2 ** step.astype(F32)

        def upd(g, mu, nu, p):
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mhat = mu / b1c
            nhat = nu / b2c
            delta = mhat / (jnp.sqrt(nhat) + eps)
            if weight_decay and _decayable(p):
                delta = delta + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr * delta).astype(p.dtype), mu, nu

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": mu, "nu": nu, "step": step,
                            "grad_norm": gnorm, "lr": lr}

    return Optimizer(init, update)


def adafactor(lr_fn, *, decay: float = 0.8, eps: float = 1e-30,
              clip_norm: float = 1.0, clip_rms: float = 1.0,
              weight_decay: float = 0.0,
              chunked_update: bool = False) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018), the
    state-memory-frugal choice for the >=100B-param archs."""

    def _state_for(p):
        if p.ndim >= 2:
            # factor over the two largest (trailing) dims; keep leading
            # dims (e.g. the stacked-layer axis) unfactored.
            row_shape = p.shape[:-1]
            col_shape = p.shape[:-2] + p.shape[-1:]
            return {"vr": jnp.zeros(row_shape, F32),
                    "vc": jnp.zeros(col_shape, F32)}
        return {"v": jnp.zeros(p.shape, F32)}

    def init(params):
        leaves = jax.tree_util.tree_leaves(params)
        # factored state is a *list* aligned with the flattened params —
        # it has deeper structure than the params tree, so tree.map over
        # the params treedef would not line up.
        return {"v": [_state_for(p) for p in leaves],
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr = lr_fn(step)
        beta = 1.0 - (step.astype(F32) + 1.0) ** (-decay)

        def upd(g, v, p):
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                rfac = (vr / jnp.maximum(denom, eps))[..., None]
                prec = jax.lax.rsqrt(jnp.maximum(rfac * vc[..., None, :], eps))
                u = g * prec
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                u = g * jax.lax.rsqrt(jnp.maximum(nv["v"], eps))
            # update clipping by RMS (Adafactor's d=1 rule)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_rms)
            if weight_decay and _decayable(p):
                u = u + weight_decay * p.astype(F32)
            return (p.astype(F32) - lr * u).astype(p.dtype), nv

        def upd_maybe_chunked(g, v, p):
            # Optional: layer-stacked leaves (L, ...) update one
            # layer-slice at a time to bound the f32 temporaries.
            # Hypothesized ~15 GiB win on the 1T config; *measured* +15 GiB
            # on the CPU buffer allocator (loop double-buffering), so off
            # by default — see EXPERIMENTS.md §Perf (refuted hypothesis).
            if chunked_update and p.ndim >= 3 and p.shape[0] >= 8:
                return jax.lax.map(lambda t: upd(*t), (g, v, p))
            return upd(g, v, p)

        gleaves, treedef = jax.tree_util.tree_flatten(grads)
        pleaves = treedef.flatten_up_to(params)
        outs = [upd_maybe_chunked(g, v, p)
                for g, v, p in zip(gleaves, state["v"], pleaves)]
        new_params = jax.tree_util.tree_unflatten(
            treedef, [o[0] for o in outs])
        v = [o[1] for o in outs]
        return new_params, {"v": v, "step": step, "grad_norm": gnorm,
                            "lr": lr}

    return Optimizer(init, update)


def make_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(name)
