"""MRG — "MapReduce Gonzalez" (paper §3, Algorithm 1), one algorithm.

``mrg(points_or_source, k, executor=...)`` runs the paper's algorithm on
any machine substrate: round 1 maps GON over the executor's machine-blocks
of the input, rounds 2+ reduce the center union under the capacity ``c``
(Lemma 2 for 2 rounds ⇒ 4-approximation; Lemma 3's multi-round
generalization adds +2 per extra level), and the covering radius is a
streamed fold over the original source. The machine notions — vmapped
blocks, mesh shards, or sequential out-of-core super-shards — live in
``repro.core.executor``; the input notions — device array, host numpy,
on-disk shards, generator program — live in ``repro.data.source``.

Thin wrappers keep the historical API:

* ``mrg_sim`` — the paper's experimental setup: ``m`` simulated machines on
  one device (``SimExecutor``: points blocked into m shards, GON on every
  shard via ``vmap``).
* ``mrg_distributed`` — the production TPU form (``MeshExecutor``: points
  sharded over mesh axes, round 1 a ``shard_map`` block, round 2 an
  ``all_gather`` + replicated GON; hierarchical gathers go axis-group by
  axis-group, mirroring Lemma 3 with ICI-domain capacities).

Out-of-core: ``mrg(HostSource(x), k)`` (or ``MemmapSource`` /
``SyntheticSource``) defaults to ``HostStreamExecutor`` — round 1 becomes a
sequential fold over DMA'd super-shards under a ``memory_budget``, so n is
bounded by host RAM or disk instead of HBM.

Paper correspondence: machines m = number of blocks; capacity c = per-
machine working-set budget (``capacity`` rows / ``memory_budget`` bytes);
"send all points in S to a single reducer" = ``Executor.combine`` (an
``all_gather`` on the mesh — the gathered set is k·m points, tiny next
to n).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Sequence

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.data.source import ArraySource, as_source, is_source

from .executor import (  # noqa: F401  (_block/_mrg_round re-exported for
    Executor,            # benchmarks/runtime_scaling.py's round-timing)
    HostStreamExecutor,
    MeshExecutor,
    SimExecutor,
    _block,
    _mrg_round,
)


class MRGResult(NamedTuple):
    centers: jnp.ndarray   # (k, d)
    radius2: jnp.ndarray   # () squared covering radius over ALL points
    rounds: int            # number of GON levels used (2 = classic MRG)
    # (k,) per-cluster weight sums when run with a weighted Objective (the
    # centers then form a weighted coreset); None on plain k-center runs.
    weights: jnp.ndarray | None = None


# ---------------------------------------------------------------------------
# Round planning (paper §3.3, inequality (1))
# ---------------------------------------------------------------------------

def plan_rounds(n: int, m: int, k: int, capacity: int) -> int:
    """Number of GON levels needed so the final instance fits ``capacity``.

    Implements the machine-count recurrence m^(i) <= m (k/c)^i + (1-(k/c)^i)
    / (1-k/c): run first-round style reductions until fewer than 2 machines
    are needed. Returns total levels (>= 2). Raises if k > capacity (the
    paper's hard feasibility requirement: a k-point instance must fit on one
    machine).
    """
    if k > capacity:
        raise ValueError(f"infeasible: k={k} exceeds single-machine capacity {capacity}")
    levels = 1
    machines = m
    while machines * k > capacity:
        machines = math.ceil(machines * k / capacity)
        levels += 1
        if levels > 64:
            raise ValueError("round planning diverged (k too close to capacity; paper §3.3 requires 2k < c)")
    return levels + 1  # +1 for the final single-machine GON


# ---------------------------------------------------------------------------
# The unified algorithm
# ---------------------------------------------------------------------------

def mrg(points, k: int, *, executor: Executor | None = None, m: int = 50,
        capacity: int | None = None, impl: str = "auto",
        chunk: int | None = None, objective=None) -> MRGResult:
    """Paper Algorithm 1 over any point source and machine substrate.

    ``points`` is anything ``repro.data.source.as_source`` accepts: an
    array (device or numpy) or an explicit ``PointSource``. Without an
    ``executor``, raw arrays and ``ArraySource`` run on ``SimExecutor(m)``
    (the historical ``mrg_sim``); an explicit host/disk/generator source
    runs on ``HostStreamExecutor()`` (the out-of-core fold) — only passing
    a ``PointSource`` opts into streaming.

    ``capacity`` (rows; default: the executor's machine size) triggers the
    multi-round path when the k·m center union would not fit on one
    machine (``MeshExecutor``'s fused device path rejects it — that
    blocking is fixed by the mesh; its streamed sharded path honors it).
    ``chunk`` streams every distance pass in row-blocks within a machine
    (see kernels/engine.py).

    Distributed out-of-core: ``mrg(sharded, k,
    executor=MeshExecutor(mesh, memory_budget=...))`` with a
    ``ShardedSource`` (or any host-backed source — auto-split into the
    paper's contiguous machine ranges) streams each shard's blocks into
    that shard's mesh address space, so no host ever holds all n rows —
    and returns bitwise-identical results to the Sim/HostStream paths on
    ref for matching blockings.

    >>> import numpy as np
    >>> x = np.random.default_rng(0).normal(size=(256, 2)).astype(np.float32)
    >>> res = mrg(x, 4, m=8)          # 8 simulated machines, 2 rounds
    >>> res.centers.shape, res.rounds
    ((4, 2), 2)

    ``objective`` (a ``core.executor.Objective``; default ``None`` = plain
    k-center, byte-for-byte the historical orchestration) generalizes the
    run: ``weighted=True`` threads the source's per-row weights through
    every round and fills ``MRGResult.weights`` with the per-cluster
    sums; ``outliers=z`` scores ``radius2`` with the top-(z+1) fold.
    """
    streamed = is_source(points) and not isinstance(points, ArraySource)
    if streamed:
        source = as_source(points)
    else:
        # Raw arrays (numpy included) keep the legacy device path on every
        # executor — only an explicit PointSource opts into streaming.
        source = points if isinstance(points, ArraySource) \
            else ArraySource(points)
    if executor is None:
        executor = (HostStreamExecutor() if streamed else SimExecutor(m=m))
    if objective is not None and objective.weighted:
        centers, r2, rounds, w = executor.mrg(
            source, k, capacity=capacity, impl=impl, chunk=chunk,
            objective=objective)
        return MRGResult(centers, r2, rounds, w)
    if objective is None:
        # Plain runs call without the kwarg so custom Executor subclasses
        # written against the pre-objective signature keep working.
        centers, r2, rounds = executor.mrg(source, k, capacity=capacity,
                                           impl=impl, chunk=chunk)
    else:
        centers, r2, rounds = executor.mrg(source, k, capacity=capacity,
                                           impl=impl, chunk=chunk,
                                           objective=objective)
    return MRGResult(centers, r2, rounds)


# ---------------------------------------------------------------------------
# Historical wrappers (API stability)
# ---------------------------------------------------------------------------

def mrg_sim(points, k: int, m: int = 50, *,
            capacity: int | None = None, impl: str = "auto",
            chunk: int | None = None) -> MRGResult:
    """Paper Algorithm 1 with m simulated machines (single device).

    ``capacity`` (default: block size n/m) triggers the multi-round path
    when the k*m center union would not fit on one machine. ``chunk``
    streams every distance pass in row-blocks (see kernels/engine.py).
    """
    return mrg(points, k, executor=SimExecutor(m=m), capacity=capacity,
               impl=impl, chunk=chunk)


def mrg_distributed(
    points,
    k: int,
    mesh: Mesh,
    *,
    shard_axes: Sequence[str] = ("data",),
    hierarchical: bool = False,
    impl: str = "auto",
    chunk: int | None = None,
):
    """Distributed MRG on a device mesh.

    ``points (n,d)`` is (re)sharded along ``shard_axes`` (n must divide the
    product of those axis sizes). Round 1: per-device GON on the local
    shard. Round 2(+): all_gather of center sets; with ``hierarchical``,
    gathers proceed one axis at a time with an intermediate GON per level
    (Lemma 3 multi-round; +2 approx per level) — used when k·m exceeds the
    working-set budget of a single gather.

    ``chunk`` bounds each device's per-pass working set to O(chunk·k) —
    the paper's capacity c decoupled from the shard size n/m, so a shard
    may exceed what an un-chunked (n/m, k) block would allow.

    Returns ``(centers (k,d) replicated, radius2 ())``.

    Version note: built on ``repro.compat.shard_map`` — runs on jax 0.4.x
    (``jax.experimental.shard_map``, ``check_rep``) and 0.6+
    (``jax.shard_map``, ``check_vma``) unchanged.
    """
    ex = MeshExecutor(mesh, shard_axes=shard_axes, hierarchical=hierarchical)
    centers, r2, _ = ex.mrg(as_source(points), k, impl=impl, chunk=chunk)
    return centers, r2
