"""MRG — "MapReduce Gonzalez" (paper §3, Algorithm 1).

Two forms:

* ``mrg_sim`` — the paper's experimental setup: ``m`` simulated machines on
  one device. Points are blocked into m shards and GON runs on every shard
  via ``vmap`` (round 1); the union of the m·k centers goes through one
  more GON (round 2). 2 rounds ⇒ 4-approximation (Lemma 2). The multi-round
  generalization (Lemma 3) re-blocks the center union while it exceeds the
  capacity ``c``, adding +2 to the factor per extra round.

* ``mrg_distributed`` — the production TPU form: points sharded over mesh
  axes, round 1 is a ``shard_map`` block running GON on the local shard,
  round 2 is an ``all_gather`` of the per-device center sets followed by a
  replicated GON (every device recomputes the tiny final instance instead
  of idling — removes the result-broadcast round; see DESIGN.md §2).
  Hierarchical (>2-round) gathers go axis-group by axis-group, exactly
  mirroring Lemma 3's capacity argument with ICI-domain capacities.

Paper correspondence: machines m = number of shards; capacity c = per-
device working-set budget; "send all points in S to a single reducer"
= all_gather (the gathered set is k·m points — tiny next to n).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.kernels import ops

from .gonzalez import covering_radius, gonzalez


class MRGResult(NamedTuple):
    centers: jnp.ndarray   # (k, d)
    radius2: jnp.ndarray   # () squared covering radius over ALL points
    rounds: int            # number of GON levels used (2 = classic MRG)


# ---------------------------------------------------------------------------
# Round planning (paper §3.3, inequality (1))
# ---------------------------------------------------------------------------

def plan_rounds(n: int, m: int, k: int, capacity: int) -> int:
    """Number of GON levels needed so the final instance fits ``capacity``.

    Implements the machine-count recurrence m^(i) <= m (k/c)^i + (1-(k/c)^i)
    / (1-k/c): run first-round style reductions until fewer than 2 machines
    are needed. Returns total levels (>= 2). Raises if k > capacity (the
    paper's hard feasibility requirement: a k-point instance must fit on one
    machine).
    """
    if k > capacity:
        raise ValueError(f"infeasible: k={k} exceeds single-machine capacity {capacity}")
    levels = 1
    machines = m
    while machines * k > capacity:
        machines = math.ceil(machines * k / capacity)
        levels += 1
        if levels > 64:
            raise ValueError("round planning diverged (k too close to capacity; paper §3.3 requires 2k < c)")
    return levels + 1  # +1 for the final single-machine GON


# ---------------------------------------------------------------------------
# Single-device simulation (paper's experimental methodology, §7.1)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "m", "impl", "chunk"))
def _mrg_round(points_blocked: jnp.ndarray, mask_blocked: jnp.ndarray,
               k: int, m: int, impl: str, chunk: int | None = None):
    """vmapped GON over m blocks -> (m*k, d) center union + validity mask."""
    res = jax.vmap(
        lambda p, mk: gonzalez(p, k, mask=mk, impl=impl, chunk=chunk)
    )(points_blocked, mask_blocked)
    centers = res.centers.reshape(m * k, -1)
    # a block with zero valid points still emits k (zero) rows; mark validity
    any_valid = jnp.any(mask_blocked, axis=1)             # (m,)
    valid = jnp.repeat(any_valid, k)                      # (m*k,)
    return centers, valid


def _block(points: jnp.ndarray, m: int):
    """Pad & reshape (n,d) -> (m, ceil(n/m), d) plus validity mask."""
    n, d = points.shape
    per = -(-n // m)
    pad = per * m - n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    mask = jnp.arange(per * m) < n
    return pts.reshape(m, per, d), mask.reshape(m, per)


def mrg_sim(points: jnp.ndarray, k: int, m: int = 50, *,
            capacity: int | None = None, impl: str = "auto",
            chunk: int | None = None) -> MRGResult:
    """Paper Algorithm 1 with m simulated machines (single device).

    ``capacity`` (default: block size n/m) triggers the multi-round path
    when the k*m center union would not fit on one machine. ``chunk``
    streams every distance pass in row-blocks (see kernels/engine.py).
    """
    n, d = points.shape
    points = points.astype(jnp.float32)
    if capacity is None:
        capacity = max(-(-n // m), 2 * k)
    levels = 1

    cur, mask = _block(points, m)
    centers, valid = _mrg_round(cur, mask, k, m, impl, chunk)
    levels += 1
    # Multi-round: while the union exceeds capacity, re-block and reduce
    # (paper §3.3 — each extra level adds +2 to the approximation factor).
    while centers.shape[0] > capacity and centers.shape[0] > k:
        m2 = -(-centers.shape[0] // capacity)  # >= 2 since rows > capacity
        blocked, bmask = _block(centers, m2)
        vpad = jnp.pad(valid, (0, bmask.size - valid.shape[0]),
                       constant_values=False)
        bmask = bmask & vpad.reshape(bmask.shape)
        centers, valid = _mrg_round(blocked, bmask, k, m2, impl, chunk)
        levels += 1

    final = gonzalez(centers, k, mask=valid, impl=impl, chunk=chunk)
    r = covering_radius(points, final.centers, impl=impl, chunk=chunk)
    return MRGResult(final.centers, r * r, levels)


# ---------------------------------------------------------------------------
# Distributed (production) form: shard_map over mesh axes
# ---------------------------------------------------------------------------

def mrg_distributed(
    points: jnp.ndarray,
    k: int,
    mesh: Mesh,
    *,
    shard_axes: Sequence[str] = ("data",),
    hierarchical: bool = False,
    impl: str = "auto",
    chunk: int | None = None,
):
    """Distributed MRG on a device mesh.

    ``points (n,d)`` is (re)sharded along ``shard_axes`` (n must divide the
    product of those axis sizes). Round 1: per-device GON on the local
    shard. Round 2(+): all_gather of center sets; with ``hierarchical``,
    gathers proceed one axis at a time with an intermediate GON per level
    (Lemma 3 multi-round; +2 approx per level) — used when k·m exceeds the
    working-set budget of a single gather.

    ``chunk`` bounds each device's per-pass working set to O(chunk·k) —
    the paper's capacity c decoupled from the shard size n/m, so a shard
    may exceed what an un-chunked (n/m, k) block would allow.

    Returns ``(centers (k,d) replicated, radius2 ())``.

    Version note: built on ``repro.compat.shard_map`` — runs on jax 0.4.x
    (``jax.experimental.shard_map``, ``check_rep``) and 0.6+
    (``jax.shard_map``, ``check_vma``) unchanged.
    """
    axes = tuple(shard_axes)
    pspec = P(axes if len(axes) > 1 else axes[0])

    @functools.partial(
        compat.shard_map,
        mesh=mesh,
        in_specs=(pspec,),
        out_specs=(P(), P()),
        check_replication=False,
    )
    def run(local):
        res = gonzalez(local, k, impl=impl, chunk=chunk)
        centers = res.centers
        if hierarchical and len(axes) > 1:
            for ax in axes:
                centers = jax.lax.all_gather(centers, ax, tiled=True)
                centers = gonzalez(centers, k, impl=impl, chunk=chunk).centers
        else:
            for ax in axes:
                centers = jax.lax.all_gather(centers, ax, tiled=True)
            centers = gonzalez(centers, k, impl=impl, chunk=chunk).centers
        # local covering radius -> global max
        _, d2 = ops.assign_nearest(local, centers, impl=impl, chunk=chunk)
        r2 = jnp.max(d2)
        for ax in axes:
            r2 = jax.lax.pmax(r2, ax)
        return centers, r2

    sharding = NamedSharding(mesh, pspec)
    points = jax.device_put(points.astype(jnp.float32), sharding)
    return run(points)
