"""Executors — the paper's "machines", unified behind one interface.

The MapReduce model of the paper (§3) is: the input lives partitioned on
``m`` machines of capacity ``c``; a round runs GON on every partition and a
reducer combines the per-machine center sets (Lemma 2 for 2 rounds, Lemma 3
for the multi-round generalization). Historically this repo hard-coded
three different machine notions — vmapped blocks in ``mrg_sim``, mesh
shards in ``mrg_distributed``, and device-resident arrays everywhere. An
``Executor`` owns that choice, so ``repro.core.mrg.mrg`` is one algorithm
over any substrate:

=================== ======================= ===================== ==========
executor            machines                capacity knob         input
=================== ======================= ===================== ==========
SimExecutor         m vmapped blocks        ``capacity`` (rows)   device
MeshExecutor        mesh shards             shard size / axes     device
HostStreamExecutor  sequential super-shards ``memory_budget`` /   host RAM /
                    DMA'd from the source   ``block_rows``        disk
=================== ======================= ===================== ==========

Interface (paper correspondence in brackets):

  * ``run_blocks(fn, source)`` — round 1 [map]: apply the per-machine
    reducer ``fn(points (rows, d), mask (rows,) bool) -> (k, d)`` to every
    machine-block of the source; returns the center union ``(M·k, d)``
    plus a validity mask.
  * ``combine(centers, valid, k, capacity)`` — rounds 2+ [reduce /
    "send all points in S to a single reducer"]: while the union exceeds
    ``capacity``, re-block and reduce again (Lemma 3, +2 to the
    approximation factor per extra level), then run the final
    single-machine GON. Runs device-side — the union is k·M rows, tiny
    next to n.
  * ``radius2(source, centers)`` — the covering-radius fold over the
    *original* source (streamed; only one block device-resident).
  * ``mrg(source, k)`` — the orchestration of the three. ``MeshExecutor``
    overrides it wholesale: its rounds are one fused ``shard_map`` program
    (all_gather instead of a host-side reduce; every device recomputes the
    tiny final instance instead of idling).

``HostStreamExecutor`` is the out-of-core form: round 1 is a sequential
fold over super-shards DMA'd from a ``HostSource``/``MemmapSource`` (double
buffered, see data/source.py), so ``mrg`` completes at n bounded by host
RAM or disk — the ROADMAP's "out-of-core input" step. Its ``memory_budget``
is the paper's machine capacity ``c`` in bytes.

jax version note: the mesh path is built on ``repro.compat.shard_map`` and
runs unchanged on jax 0.4.x and 0.6+.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.data.source import as_source
from repro.kernels import engine, ops

from .gonzalez import covering_radius, gonzalez

BlockFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


@functools.lru_cache(maxsize=None)
def gon_block_fn(k: int, impl: str = "auto",
                 chunk: int | None = None) -> BlockFn:
    """The per-machine reducer: GON restricted to a (masked) block.

    Cached on ``(k, impl, chunk)`` so repeated ``mrg`` calls reuse one
    function object — and therefore one jit cache entry per block shape.
    """
    def fn(points: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
        return gonzalez(points, k, mask=mask, impl=impl, chunk=chunk).centers
    return fn


@functools.lru_cache(maxsize=None)
def _vmapped(fn: BlockFn):
    return jax.jit(jax.vmap(fn))


def _block(points: jnp.ndarray, m: int):
    """Pad & reshape (n,d) -> (m, ceil(n/m), d) plus validity mask."""
    n, d = points.shape
    per = -(-n // m)
    pad = per * m - n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    mask = jnp.arange(per * m) < n
    return pts.reshape(m, per, d), mask.reshape(m, per)


def _run_round(points_blocked: jnp.ndarray, mask_blocked: jnp.ndarray,
               fn: BlockFn):
    """vmapped ``fn`` over m blocks -> (m*k, d) center union + validity."""
    centers = _vmapped(fn)(points_blocked, mask_blocked)   # (m, k, d)
    m, k = centers.shape[0], centers.shape[1]
    centers = centers.reshape(m * k, -1)
    # a block with zero valid points still emits k (zero) rows; mark validity
    any_valid = jnp.any(mask_blocked, axis=1)              # (m,)
    valid = jnp.repeat(any_valid, k)                       # (m*k,)
    return centers, valid


def _mrg_round(points_blocked: jnp.ndarray, mask_blocked: jnp.ndarray,
               k: int, m: int, impl: str, chunk: int | None = None):
    """PR-1-compatible round entry (benchmarks/runtime_scaling.py times it)."""
    del m  # implied by the blocking
    return _run_round(points_blocked, mask_blocked, gon_block_fn(k, impl, chunk))


class Executor:
    """Base: block-mapped round 1 + shared Lemma-3 reduction."""

    def run_blocks(self, fn: BlockFn, source):
        """Round 1: map ``fn`` over the source's machine-blocks.

        Returns ``(centers (M·k, d), valid (M·k,) bool)``.
        """
        raise NotImplementedError

    def default_capacity(self, source, k: int) -> int:
        """The paper's machine capacity ``c`` implied by this executor's
        blocking (rows per machine, floored at 2k — §3.3 requires 2k < c
        for the round recurrence to converge)."""
        return 2 * k

    def combine(self, centers: jnp.ndarray, valid: jnp.ndarray, k: int,
                capacity: int, *, impl: str = "auto",
                chunk: int | None = None):
        """Rounds 2+: reduce the center union to k centers.

        While the union exceeds ``capacity``, re-block and run another
        vmapped GON level (paper §3.3 — each extra level adds +2 to the
        approximation factor), then the final single-machine GON.
        Returns ``(centers (k, d), extra_rounds)``.
        """
        extra = 0
        while centers.shape[0] > capacity and centers.shape[0] > k:
            m2 = -(-centers.shape[0] // capacity)  # >= 2 since rows > capacity
            blocked, bmask = _block(centers, m2)
            vpad = jnp.pad(valid, (0, bmask.size - valid.shape[0]),
                           constant_values=False)
            bmask = bmask & vpad.reshape(bmask.shape)
            centers, valid = _mrg_round(blocked, bmask, k, m2, impl, chunk)
            extra += 1
        final = gonzalez(centers, k, mask=valid, impl=impl, chunk=chunk)
        return final.centers, extra

    def radius2(self, source, centers: jnp.ndarray, *, impl: str = "auto",
                chunk: int | None = None) -> jnp.ndarray:
        """Squared covering radius over ALL source points (streamed)."""
        r = jnp.sqrt(engine.fold_min_d2(source, centers, impl=impl,
                                        chunk=chunk))
        return r * r

    def mrg(self, source, k: int, *, capacity: int | None = None,
            impl: str = "auto", chunk: int | None = None):
        """Full MRG on this executor. Returns ``(centers, radius2, rounds)``."""
        source = as_source(source)
        if capacity is None:
            capacity = self.default_capacity(source, k)
        fn = gon_block_fn(k, impl, chunk)
        centers, valid = self.run_blocks(fn, source)
        centers, extra = self.combine(centers, valid, k, capacity,
                                      impl=impl, chunk=chunk)
        r2 = self.radius2(source, centers, impl=impl, chunk=chunk)
        return centers, r2, 2 + extra


class SimExecutor(Executor):
    """The paper's experimental setup (§7.1): ``m`` simulated machines on
    one device — the source is materialized and blocked into m shards, and
    GON runs on every shard via ``vmap``."""

    def __init__(self, m: int = 50):
        if m < 1:
            raise ValueError(f"need at least one machine, got m={m}")
        self.m = m

    def run_blocks(self, fn: BlockFn, source):
        x = as_source(source).materialize()
        blocked, mask = _block(x, self.m)
        return _run_round(blocked, mask, fn)

    def default_capacity(self, source, k: int) -> int:
        return max(-(-source.n // self.m), 2 * k)

    def radius2(self, source, centers, *, impl="auto", chunk=None):
        # Device-resident input: the legacy single-pass radius (identical
        # values; avoids re-blocking an array that is already in HBM).
        r = covering_radius(source.materialize(), centers, impl=impl,
                            chunk=chunk)
        return r * r


class HostStreamExecutor(Executor):
    """Out-of-core machines: sequential super-shards DMA'd from the source.

    Round 1 is a host-driven fold — each super-shard is uploaded (double
    buffered), reduced to k centers by GON, and discarded; at most two
    shards (the consumed one plus the prefetched one) and the accumulated
    union are device-resident. ``memory_budget`` (bytes) bounds both shards
    via the engine's ``2·4·rows·(d+1)`` model — the paper's machine
    capacity ``c``; ``block_rows`` sets the shard size directly.
    """

    def __init__(self, block_rows: int | None = None,
                 memory_budget: int | None = None):
        self.block_rows = block_rows
        self.memory_budget = memory_budget

    def rows_for(self, source) -> int:
        return engine.resolve_block_rows(source.n, source.d,
                                         block_rows=self.block_rows,
                                         memory_budget=self.memory_budget)

    def run_blocks(self, fn: BlockFn, source):
        rows = self.rows_for(source)
        outs = []
        for blk in source.blocks(rows):
            mask = jnp.ones((blk.shape[0],), bool)
            outs.append(fn(blk, mask))                     # (k, d) each
        centers = jnp.concatenate(outs, axis=0)            # (M*k, d)
        valid = jnp.ones((centers.shape[0],), bool)
        return centers, valid

    def default_capacity(self, source, k: int) -> int:
        return max(self.rows_for(source), 2 * k)

    def radius2(self, source, centers, *, impl="auto", chunk=None):
        r = jnp.sqrt(engine.fold_min_d2(source, centers, impl=impl,
                                        chunk=chunk,
                                        block_rows=self.rows_for(source)))
        return r * r


class MeshExecutor(Executor):
    """The production TPU form: machines are mesh shards.

    Overrides ``mrg`` wholesale — round 1 (per-shard GON), round 2+
    (all_gather of center sets + replicated GON; with ``hierarchical``,
    axis-by-axis gathers with an intermediate GON per level, exactly
    Lemma 3 with ICI-domain capacities) and the radius reduction are one
    fused ``shard_map`` program, so no host round-trips and no separate
    result-broadcast round.
    """

    def __init__(self, mesh: Mesh, shard_axes: Sequence[str] = ("data",),
                 hierarchical: bool = False):
        self.mesh = mesh
        self.shard_axes = tuple(shard_axes)
        self.hierarchical = hierarchical

    def run_blocks(self, fn: BlockFn, source):
        raise NotImplementedError(
            "MeshExecutor's rounds are one fused shard_map program; "
            "use .mrg() directly")

    def mrg(self, source, k: int, *, capacity: int | None = None,
            impl: str = "auto", chunk: int | None = None):
        if capacity is not None:
            raise ValueError(
                "MeshExecutor's machine capacity is fixed by the mesh "
                "blocking (shard size / gather tree); capacity= is not "
                "supported — use shard_axes/hierarchical instead")
        axes = self.shard_axes
        hierarchical = self.hierarchical
        pspec = P(axes if len(axes) > 1 else axes[0])

        @functools.partial(
            compat.shard_map,
            mesh=self.mesh,
            in_specs=(pspec,),
            out_specs=(P(), P()),
            check_replication=False,
        )
        def run(local):
            res = gonzalez(local, k, impl=impl, chunk=chunk)
            centers = res.centers
            if hierarchical and len(axes) > 1:
                for ax in axes:
                    centers = jax.lax.all_gather(centers, ax, tiled=True)
                    centers = gonzalez(centers, k, impl=impl,
                                       chunk=chunk).centers
            else:
                for ax in axes:
                    centers = jax.lax.all_gather(centers, ax, tiled=True)
                centers = gonzalez(centers, k, impl=impl, chunk=chunk).centers
            # local covering radius -> global max
            _, d2 = ops.assign_nearest(local, centers, impl=impl, chunk=chunk)
            r2 = jnp.max(d2)
            for ax in axes:
                r2 = jax.lax.pmax(r2, ax)
            return centers, r2

        x = as_source(source).materialize()
        sharding = NamedSharding(self.mesh, pspec)
        x = jax.device_put(x, sharding)
        centers, r2 = run(x)
        rounds = 1 + (len(axes) if hierarchical and len(axes) > 1 else 1)
        return centers, r2, rounds
