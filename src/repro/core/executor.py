"""Executors — the paper's "machines", unified behind one interface.

The MapReduce model of the paper (§3) is: the input lives partitioned on
``m`` machines of capacity ``c``; a round runs GON on every partition and a
reducer combines the per-machine center sets (Lemma 2 for 2 rounds, Lemma 3
for the multi-round generalization). Historically this repo hard-coded
three different machine notions — vmapped blocks in ``mrg_sim``, mesh
shards in ``mrg_distributed``, and device-resident arrays everywhere. An
``Executor`` owns that choice, so ``repro.core.mrg.mrg`` is one algorithm
over any substrate:

=================== ======================= ===================== ===========
executor            machines                capacity knob         input
=================== ======================= ===================== ===========
SimExecutor         m vmapped blocks        ``capacity`` (rows)   device
MeshExecutor        mesh shards             shard size / axes     device
(fused device path)                         (``hierarchical``)
MeshExecutor        mesh shards, each       ``memory_budget`` /   per-shard
(sharded streamed)  streaming its own       ``block_rows``        sources —
                    per-shard source        (per shard) +         no host
                                            ``capacity`` (rows)   holds n
HostStreamExecutor  sequential super-shards ``memory_budget`` /   host RAM /
                    DMA'd from the source   ``block_rows``        disk
=================== ======================= ===================== ===========

Interface (paper correspondence in brackets):

  * ``run_blocks(fn, source)`` — round 1 [map]: apply the per-machine
    reducer ``fn(points (rows, d), mask (rows,) bool) -> (k, d)`` to every
    machine-block of the source; returns the center union ``(M·k, d)``
    plus a validity mask.
  * ``combine(centers, valid, k, capacity)`` — rounds 2+ [reduce /
    "send all points in S to a single reducer"]: while the union exceeds
    ``capacity``, re-block and reduce again (Lemma 3, +2 to the
    approximation factor per extra level), then run the final
    single-machine GON. Runs device-side — the union is k·M rows, tiny
    next to n.
  * ``radius2(source, centers)`` — the covering-radius fold over the
    *original* source (streamed; only one block device-resident).
  * ``mrg(source, k)`` — the orchestration of the three. ``MeshExecutor``
    overrides it wholesale: its rounds are one fused ``shard_map`` program
    (all_gather instead of a host-side reduce; every device recomputes the
    tiny final instance instead of idling).

``HostStreamExecutor`` is the out-of-core form: round 1 is a sequential
fold over super-shards DMA'd from a ``HostSource``/``MemmapSource``
(prefetch-ring buffered, see data/source.py), so ``mrg`` completes at n
bounded by host RAM or disk — the ROADMAP's "out-of-core input" step. Its
``memory_budget`` is the paper's machine capacity ``c`` in bytes.

``MeshExecutor`` additionally owns the *sharded streamed* form — the
paper's model verbatim: the input arrives as a ``ShardedSource`` (one
``PointSource`` per mesh shard; ``data/source.py``), each shard streams
its own blocks into its own mesh address space, and no host ever holds
all n rows. ``memory_budget`` is then the per-*shard* capacity ``c``.

Beyond MRG, executors own one more per-iteration primitive:
``run_filter_round`` — EIM's MapReduce Rounds 2–3 (paper §4, Algorithm 2):
update the host-resident ``d(x, S)`` state against the newly sampled
centers and reduce the φ·log n-th-farthest pivot (Algorithm 3's Select) in
the same pass. ``HostStreamExecutor`` executes it as a streamed fold under
``memory_budget`` (the per-block top-k's merge exactly — see
``engine.merge_top_k``); ``SimExecutor`` keeps the vmapped-machines
simulation (per-machine update + per-machine top-k, merged like the
MapReduce shuffle would). Both produce bitwise-identical ``d_s`` and pivot
for the same inputs on the ref backend — value reductions (min, top-k
values) are blocking-invariant.

jax version note: the mesh path is built on ``repro.compat.shard_map`` and
runs unchanged on jax 0.4.x and 0.6+.
"""
from __future__ import annotations

import functools
import warnings
import weakref
from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.data.source import (ArraySource, ShardedSource, as_source,
                               shard_source, stream_device, weights_of)
from repro.kernels import engine, ops

from .gonzalez import gonzalez

BlockFn = Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
# Weighted round-1 reducer: (points (rows,d), mask (rows,), w (rows,)) ->
# (centers (k,d), cluster weights (k,)).
WeightedBlockFn = Callable[
    [jnp.ndarray, jnp.ndarray, jnp.ndarray],
    tuple[jnp.ndarray, jnp.ndarray]]


@dataclass(frozen=True)
class Objective:
    """Pluggable fold objective over the source × executor substrate.

    The executors' round surface (``run_blocks`` / ``combine_weighted`` /
    ``radius2`` / ``mrg``) dispatches on this descriptor instead of
    hard-coding unit-weight plain k-center:

    * ``weighted`` — round 1 reduces *weighted* instances: the per-machine
      reducer also emits per-cluster weight sums (the coreset outputs of
      Ceccarello et al. 1802.09205 — each center stands in for its
      cluster's total weight), carried through the Lemma-3 combine so the
      final k centers arrive with their cluster weights.
    * ``outliers`` — z: ``radius2`` becomes the top-(z+1) evaluation fold
      (squared covering radius after excluding the z farthest points),
      i.e. the (k,z)-center objective value.

    The default descriptor (``Objective()``, equivalently passing
    ``objective=None``) is plain k-center and keeps every executor code
    path *literally* unchanged — the bitwise contract the parity tests
    pin. Center *selection* is weight-oblivious throughout (k-center's
    max-min objective over the support doesn't scale with multiplicity),
    which is also what makes unit-weight weighted runs bitwise the plain
    runs.
    """

    name: str = "kcenter"
    weighted: bool = False
    outliers: int = 0

    def __post_init__(self):
        if self.outliers < 0:
            raise ValueError(f"outliers must be >= 0, got {self.outliers}")


def _is_plain(objective: Objective | None) -> bool:
    return objective is None or (not objective.weighted
                                 and objective.outliers == 0)

# np scalars so importing this module never commits the jax backend
_NEG = np.float32(-3.4e38)   # Select's invalid-slot sentinel (matches eim)
_BIG = np.float32(3.4e38)


# One super-shard's share of EIM Rounds 2–3, fused and jitted: the engine
# owns the implementation (it dispatches between the jnp oracle and the
# fused Pallas streamed tile — bitwise-identical); the historical name
# stays for callers and tests.
_eim_filter_block = engine.eim_filter_block


@functools.partial(jax.jit, static_argnames=("rank",))
def _eim_pivot_block(d_blk, h_blk, top, w_blk=None, *, rank):
    """Pivot-only block step for a zero-sample iteration (the distance
    state must stay bit-for-bit untouched, like the device path's
    ``any(s_valid)`` gate). ``w_blk=None`` is an empty jit pytree leaf —
    the unweighted compiled program is byte-identical to the pre-weights
    one; when present, ``w <= 0`` rows are gated out like ``H=False``."""
    sel = h_blk if w_blk is None else h_blk & (w_blk > 0)
    cand = jnp.where(sel, d_blk, _NEG)
    return engine.merge_top_k(top, cand, rank)


def _pivot_from_top(top: jnp.ndarray, rank: int) -> np.float32:
    """Algorithm 3's pivot from a merged descending top-``rank``: the
    rank-th largest d(·,S)^2, or -1.0 when fewer than ``rank`` valid points
    existed (sentinel slots survive the merge) — no distance-based removals
    that iteration, exactly the device path's ``where(pivot <= _NEG/2)``."""
    pivot = np.float32(np.asarray(top)[rank - 1])
    if pivot <= np.float32(_NEG) / 2:
        return np.float32(-1.0)
    return pivot


@functools.lru_cache(maxsize=None)
def gon_block_fn(k: int, impl: str = "auto",
                 chunk: int | None = None) -> BlockFn:
    """The per-machine reducer: GON restricted to a (masked) block.

    Cached on ``(k, impl, chunk)`` so repeated ``mrg`` calls reuse one
    function object — and therefore one jit cache entry per block shape.
    """
    def fn(points: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
        return gonzalez(points, k, mask=mask, impl=impl, chunk=chunk).centers
    return fn


@functools.lru_cache(maxsize=None)
def weighted_gon_block_fn(k: int, impl: str = "auto",
                          chunk: int | None = None, *,
                          mask_zero: bool = True) -> WeightedBlockFn:
    """The weighted per-machine reducer: masked GON + per-cluster weight
    sums — one machine's share of a weighted coreset (Ceccarello et al.
    1802.09205: the per-reducer weighted instance).

    Selection runs the *same* masked GON as ``gon_block_fn`` (k-center's
    objective is weight-oblivious over the support), then each valid row's
    weight is summed onto its nearest selected center. f32 sums of
    integer-valued weights (cluster counts) are exact below 2^24.
    ``mask_zero`` additionally drops ``w <= 0`` rows from selection (they
    are absent from the instance) — round 1 wants that; the combine levels
    pass ``mask_zero=False`` so their selection mask is *exactly* the
    plain ``combine``'s (zero-weight duplicate rows from short blocks stay
    selectable there, keeping unit-weight runs bitwise plain).
    """
    def fn(points: jnp.ndarray, mask: jnp.ndarray, w: jnp.ndarray):
        sel = mask & (w > 0) if mask_zero else mask
        centers = gonzalez(points, k, mask=sel, impl=impl,
                           chunk=chunk).centers
        idx, _ = ops.assign_nearest(points, centers, impl=impl, chunk=chunk)
        cw = jnp.zeros((k,), jnp.float32).at[idx].add(
            jnp.where(sel, w, 0.0))
        return centers, cw
    return fn


@functools.lru_cache(maxsize=None)
def _vmapped(fn: BlockFn):
    return jax.jit(jax.vmap(fn))


def _block(points: jnp.ndarray, m: int):
    """Pad & reshape (n,d) -> (m, ceil(n/m), d) plus validity mask."""
    n, d = points.shape
    per = -(-n // m)
    pad = per * m - n
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    mask = jnp.arange(per * m) < n
    return pts.reshape(m, per, d), mask.reshape(m, per)


def _run_round(points_blocked: jnp.ndarray, mask_blocked: jnp.ndarray,
               fn: BlockFn):
    """vmapped ``fn`` over m blocks -> (m*k, d) center union + validity."""
    centers = _vmapped(fn)(points_blocked, mask_blocked)   # (m, k, d)
    m, k = centers.shape[0], centers.shape[1]
    centers = centers.reshape(m * k, -1)
    # a block with zero valid points still emits k (zero) rows; mark validity
    any_valid = jnp.any(mask_blocked, axis=1)              # (m,)
    valid = jnp.repeat(any_valid, k)                       # (m*k,)
    return centers, valid


def _run_round_w(points_blocked: jnp.ndarray, mask_blocked: jnp.ndarray,
                 w_blocked: jnp.ndarray, fn: WeightedBlockFn):
    """Weighted ``_run_round``: also flattens the per-block cluster-weight
    sums -> ``(centers (m*k, d), valid (m*k,), weights (m*k,))``."""
    centers, cw = _vmapped(fn)(points_blocked, mask_blocked, w_blocked)
    m, k = centers.shape[0], centers.shape[1]
    any_valid = jnp.any(mask_blocked, axis=1)
    valid = jnp.repeat(any_valid, k)
    return centers.reshape(m * k, -1), valid, cw.reshape(-1)


def _mrg_round(points_blocked: jnp.ndarray, mask_blocked: jnp.ndarray,
               k: int, m: int, impl: str, chunk: int | None = None):
    """PR-1-compatible round entry (benchmarks/runtime_scaling.py times it)."""
    del m  # implied by the blocking
    return _run_round(points_blocked, mask_blocked, gon_block_fn(k, impl, chunk))


_DIVERGED_MSG = ("combine diverged (k too close to capacity; "
                 "paper §3.3 requires 2k < c)")


def check_combine_capacity(k: int, capacity: int, *,
                           warn: bool = True) -> None:
    """Feasibility of the Lemma-3 reduction under machine capacity ``c``.

    Mirrors ``plan_rounds``' checks so ``mrg()``/``combine`` fail up front
    instead of looping forever: a level re-blocks M rows into
    ``m2 = ceil(M / capacity)`` machines and emits ``m2·k`` rows, so with
    ``capacity <= k`` the union never shrinks (e.g. ``mrg(x, 8,
    capacity=4)``: 400 rows → m2=100 → 800 rows, growing every level) —
    hard error. With ``k < capacity < 2k`` the recurrence may still stall
    on the ceil (§3.3 requires ``2k < c`` for convergence) — warn (unless
    ``warn=False``; ``mrg`` pre-checks with it off so the warning fires
    once, from ``combine``), and let the divergence guard in ``combine``
    raise if it does.
    """
    if capacity <= k:
        raise ValueError(
            f"infeasible: k={k} needs single-machine capacity > k, got "
            f"{capacity} — every combine level re-blocks M rows into "
            "ceil(M/capacity) machines of k centers each, so the center "
            "union never shrinks")
    if warn and capacity < 2 * k:
        warnings.warn(
            f"capacity={capacity} < 2k={2 * k}: paper §3.3 requires "
            "2k < c for the round recurrence to converge; combine may "
            "stall and raise", RuntimeWarning, stacklevel=3)


class Executor:
    """Base: block-mapped round 1 + shared Lemma-3 reduction."""

    def run_blocks(self, fn, source, *, objective: Objective | None = None):
        """Round 1: map ``fn`` over the source's machine-blocks.

        Plain (default) objective: ``fn`` is a ``BlockFn`` and the return
        is ``(centers (M·k, d), valid (M·k,) bool)`` — exactly the
        pre-objective surface. With ``objective.weighted``, ``fn`` is a
        ``WeightedBlockFn`` (e.g. ``weighted_gon_block_fn``) and the
        return gains the per-cluster weight sums:
        ``(centers, valid, weights (M·k,) f32)``.
        """
        raise NotImplementedError

    def default_capacity(self, source, k: int) -> int:
        """The paper's machine capacity ``c`` implied by this executor's
        blocking (rows per machine, floored at 2k — §3.3 requires 2k < c
        for the round recurrence to converge)."""
        return 2 * k

    def combine(self, centers: jnp.ndarray, valid: jnp.ndarray, k: int,
                capacity: int, *, impl: str = "auto",
                chunk: int | None = None):
        """Rounds 2+: reduce the center union to k centers.

        While the union exceeds ``capacity``, re-block and run another
        vmapped GON level (paper §3.3 — each extra level adds +2 to the
        approximation factor), then the final single-machine GON.
        Returns ``(centers (k, d), extra_rounds)``.

        ``capacity`` is validated up front (``check_combine_capacity``):
        ``capacity <= k`` makes every level *grow* the union, so it raises
        instead of looping forever; ``capacity < 2k`` warns (§3.3) and a
        divergence guard raises if a level fails to shrink the union (or
        more than 64 levels accumulate — the same bound ``plan_rounds``
        enforces).
        """
        check_combine_capacity(k, capacity)
        extra = 0
        while centers.shape[0] > capacity and centers.shape[0] > k:
            m2 = -(-centers.shape[0] // capacity)  # >= 2 since rows > capacity
            if m2 * k >= centers.shape[0] or extra >= 64:
                # With capacity >= 2k a level always shrinks the union
                # (m2*k <= M/2 + k < M); reaching here means the warned
                # k < capacity < 2k regime stalled on the ceil.
                raise ValueError(_DIVERGED_MSG)
            blocked, bmask = _block(centers, m2)
            vpad = jnp.pad(valid, (0, bmask.size - valid.shape[0]),
                           constant_values=False)
            bmask = bmask & vpad.reshape(bmask.shape)
            centers, valid = _mrg_round(blocked, bmask, k, m2, impl, chunk)
            extra += 1
        final = gonzalez(centers, k, mask=valid, impl=impl, chunk=chunk)
        return final.centers, extra

    def combine_weighted(self, centers: jnp.ndarray, valid: jnp.ndarray,
                         weights: jnp.ndarray, k: int, capacity: int, *,
                         impl: str = "auto", chunk: int | None = None,
                         final_gon: bool = True):
        """Lemma-3 reduction carrying cluster weights — coreset outputs
        stay weighted instances through every level.

        Each level re-blocks the weighted union and picks per-block GON
        centers with *exactly* ``combine``'s selection mask (validity
        only — weights never steer selection, so on unit-weight inputs the
        per-level center unions are bitwise the plain ``combine``'s), then
        re-aggregates every row's weight onto its nearest new center
        (Ceccarello et al.'s coreset re-weighting; f32 sums of integer
        weights are exact below 2^24). With ``final_gon=False`` the
        reduction stops as soon as the union fits ``capacity`` — the
        weighted-coreset form ``core.outliers.kz_center`` hands to its
        host-side solve — otherwise the final single-machine GON runs and
        the weights are re-aggregated onto the k winners.

        Returns ``(centers, weights, valid, extra_rounds)``; after a
        final GON, ``centers`` is (k, d) and ``valid`` all-True.
        """
        check_combine_capacity(k, capacity)
        w = jnp.asarray(weights, jnp.float32)
        fn = weighted_gon_block_fn(k, impl, chunk, mask_zero=False)
        extra = 0
        while centers.shape[0] > capacity and centers.shape[0] > k:
            m2 = -(-centers.shape[0] // capacity)
            if m2 * k >= centers.shape[0] or extra >= 64:
                raise ValueError(_DIVERGED_MSG)
            blocked, bmask = _block(centers, m2)
            vpad = jnp.pad(valid, (0, bmask.size - valid.shape[0]),
                           constant_values=False)
            bmask = bmask & vpad.reshape(bmask.shape)
            wpad = jnp.pad(w, (0, bmask.size - w.shape[0]))
            centers, valid, w = _run_round_w(blocked, bmask,
                                             wpad.reshape(bmask.shape), fn)
            extra += 1
        if not final_gon:
            return centers, w, valid, extra
        final = gonzalez(centers, k, mask=valid, impl=impl, chunk=chunk)
        idx, _ = ops.assign_nearest(centers, final.centers, impl=impl,
                                    chunk=chunk)
        w_out = jnp.zeros((k,), jnp.float32).at[idx].add(
            jnp.where(valid, w, 0.0))
        return (final.centers, w_out,
                jnp.ones((k,), bool), extra)

    def radius2(self, source, centers: jnp.ndarray, *, impl: str = "auto",
                chunk: int | None = None,
                objective: Objective | None = None) -> jnp.ndarray:
        """Squared covering radius over ALL source points (streamed).

        Returns the squared fold ``max(min_d2)`` *directly* — no
        ``sqrt(d2)`` → ``r*r`` round-trip, which is lossy in f32 (the fold
        is already squared). All executor paths return the same exact
        value, which is what the cross-path bitwise parity tests compare.

        A non-plain ``objective`` generalizes the fold: ``outliers=z``
        evaluates the (k,z) objective — the top-(z+1) streamed fold's last
        slot, i.e. the covering radius after excluding the z farthest
        points — and ``weighted`` restricts candidacy to the source's
        positive-weight support. The default objective takes the exact
        pre-objective code path.
        """
        if not _is_plain(objective):
            top = engine.fold_top_k_min_d2(
                source, centers, objective.outliers + 1, impl=impl,
                chunk=chunk, weighted=objective.weighted)
            return jnp.maximum(top[objective.outliers], jnp.float32(0.0))
        return engine.fold_min_d2(source, centers, impl=impl, chunk=chunk)

    def run_filter_round(self, source, s_new, d_s: np.ndarray,
                         h_mask: np.ndarray, rank: int, *,
                         impl: str = "auto", chunk: int | None = None,
                         weights: np.ndarray | None = None):
        """One EIM iteration's Rounds 2–3 over this executor's machines.

        ``s_new`` is the iteration's newly sampled centers ``(m_new, d)``
        (host numpy, possibly padded with far-away ``1e18`` sentinel rows
        to a fixed capacity — padding can never win the distance min;
        ``None``/empty for a zero-sample iteration — the distance state is
        then left untouched, like the device path's ``any(s_valid)``
        gate). ``d_s (n,) f32`` and ``h_mask (n,) bool`` are host-resident
        per-point state. Updates ``d_s`` in place to
        ``min(d_s, d(x, S_new)^2)`` (paper §4 Round 3's incremental
        update) and reduces Select's pivot — the ``rank``-th largest
        updated ``d_s`` over H (Round 2) — in the same pass.

        ``weights`` (optional host ``(n,) f32``, aligned with ``d_s``)
        threads the weighted objective through the fused update+top-k:
        ``w <= 0`` rows are gated out of pivot candidacy exactly like
        ``h_mask=False`` rows (their d(x,S) still updates). ``None`` — the
        only form existing callers pass — runs the exact pre-weights
        program.

        Returns ``(d_s, pivot)`` with ``pivot`` an np.float32 (−1.0 when H
        held fewer than ``rank`` points).
        """
        raise NotImplementedError(
            f"{type(self).__name__} does not implement EIM's "
            "run_filter_round; use HostStreamExecutor (streamed), "
            "SimExecutor (vmapped machines) or MeshExecutor (sharded)")

    def end_filter_rounds(self, source) -> None:
        """Called once when an EIM run's iteration loop finishes — the
        hook for executors to release any per-source state they cached
        across ``run_filter_round`` calls. Default: nothing to release."""

    def mrg(self, source, k: int, *, capacity: int | None = None,
            impl: str = "auto", chunk: int | None = None,
            objective: Objective | None = None):
        """Full MRG on this executor. Returns ``(centers, radius2,
        rounds)`` — or, with a weighted ``objective``, ``(centers,
        radius2, rounds, weights (k,))``: the same rounds run weighted
        (``weighted_gon_block_fn`` + ``combine_weighted``), so the k
        centers arrive with their cluster weights (a weighted coreset).
        An ``outliers=z`` objective scores ``radius2`` as the top-(z+1)
        evaluation fold; the default objective is byte-for-byte the
        pre-objective orchestration."""
        source = as_source(source)
        if capacity is None:
            capacity = self.default_capacity(source, k)
        # Fail on an infeasible capacity *before* the round-1 pass over
        # all of n, not inside combine's reduction loop (warn=False:
        # combine's own check owns the §3.3 warning).
        check_combine_capacity(k, capacity, warn=False)
        if objective is not None and objective.weighted:
            wfn = weighted_gon_block_fn(k, impl, chunk)
            centers, valid, cw = self.run_blocks(wfn, source,
                                                 objective=objective)
            centers, w, _, extra = self.combine_weighted(
                centers, valid, cw, k, capacity, impl=impl, chunk=chunk)
            r2 = self.radius2(source, centers, impl=impl, chunk=chunk,
                              objective=objective)
            return centers, r2, 2 + extra, w
        fn = gon_block_fn(k, impl, chunk)
        centers, valid = self.run_blocks(fn, source)
        centers, extra = self.combine(centers, valid, k, capacity,
                                      impl=impl, chunk=chunk)
        r2 = self.radius2(source, centers, impl=impl, chunk=chunk,
                          objective=objective)
        return centers, r2, 2 + extra


class SimExecutor(Executor):
    """The paper's experimental setup (§7.1): ``m`` simulated machines on
    one device — the source is materialized and blocked into m shards, and
    GON runs on every shard via ``vmap``."""

    def __init__(self, m: int = 50):
        if m < 1:
            raise ValueError(f"need at least one machine, got m={m}")
        self.m = m

    def run_blocks(self, fn, source, *, objective: Objective | None = None):
        src = as_source(source)
        x = src.materialize()
        blocked, mask = _block(x, self.m)
        if objective is not None and objective.weighted:
            w = jnp.asarray(weights_of(src, 0, src.n))
            wb = jnp.pad(w, (0, mask.size - w.shape[0]))
            return _run_round_w(blocked, mask, wb.reshape(mask.shape), fn)
        return _run_round(blocked, mask, fn)

    def default_capacity(self, source, k: int) -> int:
        return max(-(-source.n // self.m), 2 * k)

    def radius2(self, source, centers, *, impl="auto", chunk=None,
                objective: Objective | None = None):
        # Device-resident input: one single-pass fold (avoids re-blocking
        # an array that is already in HBM). Returns the squared max
        # directly — the sqrt→square round-trip of ``covering_radius`` is
        # lossy in f32 and would break cross-path bitwise parity.
        src = as_source(source)
        # reprolint: disable=R002 -- SimExecutor simulates m machines on one device; inputs are device-resident by contract
        _, d2 = ops.assign_nearest(src.materialize(), centers, impl=impl,
                                   chunk=chunk)
        if _is_plain(objective):
            return jnp.max(d2)
        # Same eager d2; the objective only changes the reduction (top-1
        # of a multiset == its max, so weighted unit runs keep the bits).
        if objective.weighted:
            w = jnp.asarray(weights_of(src, 0, src.n))
            d2 = jnp.where(w > 0, d2, _NEG)
        r = objective.outliers + 1
        top = engine.merge_top_k(engine.top_k_init(r), d2, r)
        return jnp.maximum(top[r - 1], jnp.float32(0.0))

    def _blocked_for(self, source):
        """Materialize + block once per source object (EIM calls the
        filter round every iteration with the same source; the points
        never change across iterations). Weakref-keyed so a different
        source object can never hit a stale cache, and released by
        ``end_filter_rounds`` so the blocked copy does not outlive the
        run. Un-weakref-able inputs are simply not cached."""
        cache = getattr(self, "_eim_blocked_cache", None)
        if cache is not None and cache[0]() is source:
            return cache[1]
        x = as_source(source).materialize()
        blocked, _ = _block(x, self.m)
        try:
            self._eim_blocked_cache = (weakref.ref(source),
                                       (x.shape[0], blocked))
        except TypeError:
            pass
        return x.shape[0], blocked

    def end_filter_rounds(self, source) -> None:
        self._eim_blocked_cache = None

    def run_filter_round(self, source, s_new, d_s, h_mask, rank, *,
                         impl="auto", chunk=None, weights=None):
        """Vmapped-machines EIM round: each of the m blocks updates its
        slice of d(x,S) against S_new and emits a per-machine top-k; the
        host merge of those tops is the simulated shuffle. ``weights``
        (optional, aligned with ``d_s``) gates ``w <= 0`` rows out of
        pivot candidacy — ``None`` runs the exact pre-weights program."""
        n, blocked = self._blocked_for(source)              # (m, per, d)
        m, per = blocked.shape[0], blocked.shape[1]
        pad = m * per - n
        # Padded rows: _BIG distance but H=False, so they can't enter the
        # pivot top-k and their d_s is dropped on the un-pad below.
        d_b = jnp.pad(jnp.asarray(d_s), (0, pad),
                      constant_values=_BIG).reshape(m, per)
        h_b = jnp.pad(jnp.asarray(h_mask), (0, pad),
                      constant_values=False).reshape(m, per)
        w_b = None
        if weights is not None:
            # Padded lanes at weight 0 — gated out of candidacy like H=0.
            w_b = jnp.pad(jnp.asarray(np.asarray(weights, np.float32)),
                          (0, pad)).reshape(m, per)
        have_s = s_new is not None and len(s_new) > 0
        use_pallas, _ = engine._resolve(impl)
        if have_s:
            c = jnp.asarray(np.asarray(s_new, np.float32))
            if use_pallas:
                # Fused tile path: vmap over a pallas_call is not a
                # supported lowering everywhere, and the machine axis is a
                # simulation artifact — flatten it. The per-row d-update
                # is machine-oblivious and the global top-k values equal
                # the merge of per-machine top-k's (value folds are
                # blocking-invariant), so this is bitwise the vmapped ref.
                d_flat, top = engine.filter_tile_update(
                    blocked.reshape(m * per, -1), c, d_b.reshape(-1),
                    h_b.reshape(-1), rank=rank, impl=impl, chunk=chunk,
                    w_blk=None if w_b is None else w_b.reshape(-1))
                d_s[:] = np.asarray(d_flat[:n])
                top = engine.merge_top_k(engine.top_k_init(rank), top, rank)
                return d_s, _pivot_from_top(top, rank)

            def update(pts, dvec):
                _, dn = ops.assign_nearest(pts, c, impl=impl, chunk=chunk)
                return jnp.minimum(dvec, dn)

            d_b = jax.vmap(update)(blocked, d_b)
            d_s[:] = np.asarray(d_b.reshape(-1)[:n])
        cand_mask = h_b if w_b is None else h_b & (w_b > 0)
        cand = jnp.where(cand_mask, d_b, _NEG)
        r = min(rank, per)
        tops = jax.vmap(lambda v: jax.lax.top_k(v, r)[0])(cand)  # (m, r)
        top = jax.lax.top_k(tops.reshape(-1), rank)[0]
        return d_s, _pivot_from_top(top, rank)


class HostStreamExecutor(Executor):
    """Out-of-core machines: sequential super-shards DMA'd from the source.

    Round 1 is a host-driven fold — each super-shard is uploaded (through
    the source's prefetch ring), reduced to k centers by GON, and
    discarded; at most ``1 + prefetch`` shards (the consumed one plus the
    in-flight ring) and the accumulated union are device-resident.
    ``memory_budget`` (bytes) bounds all of them via the engine's
    ``(1+prefetch)·4·rows·(d+1)`` model — the paper's machine capacity
    ``c``; ``block_rows`` sets the shard size directly.
    """

    def __init__(self, block_rows: int | None = None,
                 memory_budget: int | None = None,
                 prefetch: int = engine.DEFAULT_PREFETCH):
        self.block_rows = block_rows
        self.memory_budget = memory_budget
        if prefetch < 1:
            raise ValueError(f"prefetch must be >= 1, got {prefetch}")
        self.prefetch = prefetch

    def rows_for(self, source) -> int:
        return engine.resolve_block_rows(source.n, source.d,
                                         block_rows=self.block_rows,
                                         memory_budget=self.memory_budget,
                                         prefetch=self.prefetch)

    def _blocks(self, source, rows: int):
        return engine._source_blocks(source, rows, self.prefetch)

    def run_blocks(self, fn, source, *, objective: Objective | None = None):
        rows = self.rows_for(source)
        weighted = objective is not None and objective.weighted
        outs, wouts = [], []
        off = 0
        for blk in self._blocks(source, rows):
            nb = blk.shape[0]
            w_blk = None
            if weighted:
                # Padded lanes carry weight 0 — like mask=False, they can
                # never contribute to the per-cluster weight sums.
                w_np = np.zeros((rows,), np.float32)
                w_np[:nb] = engine._source_weights(source, off, nb)
                w_blk = jnp.asarray(w_np)
            if nb < rows:
                # Pad the ragged final block to the common shape and mask
                # the padding off: one compilation of the per-machine GON
                # serves every block (the mask is a traced operand, and a
                # masked GON picks bitwise-identical centers — padded rows
                # sit at the _NEG sentinel and can never be selected).
                blk = jnp.pad(blk, ((0, rows - nb), (0, 0)))
            mask = jnp.arange(rows) < nb
            if weighted:
                c, cw = fn(blk, mask, w_blk)               # (k, d), (k,)
                outs.append(c)
                wouts.append(cw)
            else:
                outs.append(fn(blk, mask))                 # (k, d) each
            off += nb
        centers = jnp.concatenate(outs, axis=0)            # (M*k, d)
        valid = jnp.ones((centers.shape[0],), bool)
        if weighted:
            return centers, valid, jnp.concatenate(wouts, axis=0)
        return centers, valid

    def default_capacity(self, source, k: int) -> int:
        return max(self.rows_for(source), 2 * k)

    def radius2(self, source, centers, *, impl="auto", chunk=None,
                objective: Objective | None = None):
        if not _is_plain(objective):
            top = engine.fold_top_k_min_d2(
                source, centers, objective.outliers + 1, impl=impl,
                chunk=chunk, block_rows=self.rows_for(source),
                prefetch=self.prefetch, weighted=objective.weighted)
            return jnp.maximum(top[objective.outliers], jnp.float32(0.0))
        return engine.fold_min_d2(source, centers, impl=impl, chunk=chunk,
                                  block_rows=self.rows_for(source),
                                  prefetch=self.prefetch)

    def run_filter_round(self, source, s_new, d_s, h_mask, rank, *,
                         impl="auto", chunk=None, weights=None):
        """EIM Rounds 2–3 as one out-of-core fold: each super-shard's
        d(x, S_new) update and its contribution to Select's top-k happen
        while the shard is device-resident; only the shard, S_new, and the
        (rank,)-sized running top-k occupy the device. The per-point state
        (d_s, h_mask) stays host-resident — O(n) bytes next to the (n, d)
        points that never materialize.

        ``source`` may be a compacted ``IndexedSource`` view (``d_s`` /
        ``h_mask`` then hold the per-view slices). Every block is padded
        to the resolved ``rows`` shape — padded lanes carry ``H=False``
        (never enter the pivot top-k) and their distance update is
        discarded — so one compilation of the fused block kernel serves
        all iterations over a given view, ragged tail included.

        ``weights`` (optional, aligned with ``d_s``) gates ``w <= 0``
        rows out of pivot candidacy; ``None`` (every plain caller) keeps
        the block programs byte-identical to the pre-weights ones."""
        rows = self.rows_for(source)
        have_s = s_new is not None and len(s_new) > 0
        if have_s:
            c = jnp.asarray(np.asarray(s_new, np.float32))
        top = engine.top_k_init(rank)
        off = 0
        for blk in self._blocks(source, rows):
            nb = blk.shape[0]
            d_np = d_s[off:off + nb]
            h_np = h_mask[off:off + nb]
            w_blk = None
            if weights is not None:
                w_np = np.zeros((rows,), np.float32)
                w_np[:nb] = np.asarray(weights[off:off + nb], np.float32)
                w_blk = jnp.asarray(w_np)
            if nb < rows:
                pad = rows - nb
                blk = jnp.pad(blk, ((0, pad), (0, 0)))
                d_np = np.concatenate(
                    [d_np, np.full(pad, np.float32(3.4e38), np.float32)])
                h_np = np.concatenate([h_np, np.zeros(pad, bool)])
            d_blk = jnp.asarray(d_np)
            h_blk = jnp.asarray(h_np)
            if have_s:
                d_blk, top = _eim_filter_block(blk, c, d_blk, h_blk, top,
                                               w_blk, rank=rank, impl=impl,
                                               chunk=chunk)
                d_s[off:off + nb] = np.asarray(d_blk)[:nb]
            else:
                top = _eim_pivot_block(d_blk, h_blk, top, w_blk, rank=rank)
            off += nb
        return d_s, _pivot_from_top(top, rank)


class MeshExecutor(Executor):
    """The production TPU form: machines are mesh shards.

    Two input regimes share the executor:

    * **Device-resident** (raw arrays / ``ArraySource``): ``mrg`` is one
      fused ``shard_map`` program — round 1 (per-shard GON), round 2+
      (all_gather of center sets + replicated GON; with ``hierarchical``,
      axis-by-axis gathers with an intermediate GON per level, exactly
      Lemma 3 with ICI-domain capacities) and the radius reduction, with
      no host round-trips. The input is materialized then resharded, so
      n is bounded by single-host RAM — the historical behavior.
    * **Sharded / streamed** (a ``ShardedSource``, or any host/disk/
      generator source — auto-split by ``shard_source`` into the paper's
      contiguous machine ranges): round 1 streams each shard's blocks
      host→device *into that shard's mesh address space* through the
      sources' prefetch ring (``compat.global_array_from_shards`` — per-
      shard DMA, no global host staging buffer), one ``shard_map`` program
      of per-shard GONs per step. **No host buffer ever holds all n
      rows**: per-shard residency is bounded by ``memory_budget`` via the
      same ``(1+prefetch)·4·rows·(d+1)`` model as ``HostStreamExecutor``,
      applied per shard. Rounds 2+ reuse the shared Lemma-3 ``combine``
      (``capacity`` is honored on this path), the covering radius is a
      per-step sharded fold, and EIM's ``run_filter_round`` streams the
      same way — so ``mrg``/``eim`` over a ``ShardedSource`` are
      *bitwise identical* to the Sim/HostStream paths on ref for matching
      machine blockings (tests/test_distributed.py pins the grid).
    """

    def __init__(self, mesh: Mesh, shard_axes: Sequence[str] = ("data",),
                 hierarchical: bool = False, *,
                 block_rows: int | None = None,
                 memory_budget: int | None = None,
                 prefetch: int = engine.DEFAULT_PREFETCH):
        self.mesh = mesh
        self.shard_axes = tuple(shard_axes)
        self.hierarchical = hierarchical
        self.block_rows = block_rows
        self.memory_budget = memory_budget
        if prefetch < 1:
            raise ValueError(f"prefetch must be >= 1, got {prefetch}")
        self.prefetch = prefetch
        self._step_cache: dict = {}

    # -- the machine blocking the mesh implies ------------------------------

    @property
    def num_shards(self) -> int:
        """Machines = product of the ``shard_axes`` mesh-axis sizes."""
        count = 1
        for ax in self.shard_axes:
            count *= int(self.mesh.shape[ax])
        return count

    def _pspec(self) -> P:
        axes = self.shard_axes
        return P(axes if len(axes) > 1 else axes[0])

    def _sharded(self, source) -> ShardedSource:
        """The per-shard view of ``source``: a ``ShardedSource`` passes
        through (its shard count must match the mesh blocking — a
        mismatch is a real partitioning bug, not something to silently
        re-split); anything else is split into the paper's contiguous
        machine ranges (zero-copy ``SliceSource`` views)."""
        src = as_source(source)
        if isinstance(src, ShardedSource):
            if src.num_shards != self.num_shards:
                raise ValueError(
                    f"ShardedSource has {src.num_shards} shards but the "
                    f"mesh blocking over {self.shard_axes} has "
                    f"{self.num_shards} — re-shard the input or change "
                    "shard_axes")
            return src
        return shard_source(src, self.num_shards)

    def rows_for(self, source) -> int:
        """Per-shard super-shard rows: ``memory_budget`` (bytes, *per
        shard*) solved against the ring residency model, like
        ``HostStreamExecutor`` but per machine."""
        sh = self._sharded(source)
        return engine.resolve_block_rows(max(sh.max_shard_rows, 1), sh.d,
                                         block_rows=self.block_rows,
                                         memory_budget=self.memory_budget,
                                         prefetch=self.prefetch)

    # -- multi-process topology ---------------------------------------------

    def _local_ids(self, sh: ShardedSource):
        """Shard indices this process feeds, or ``None`` for "all"
        (single-process — the historical behavior, kept byte-identical).

        Under ``jax.distributed`` each process feeds exactly the shards
        whose mesh address space it owns (``compat.local_shard_indices``);
        a ``ProcessShardedSource`` must hold real data for all of them —
        a mismatch between the data partition and the mesh partition is a
        launch bug, reported here rather than as a RemoteShard read deep
        inside a fold."""
        src_local = getattr(sh, "local_shard_ids", None)
        if compat.process_count() == 1:
            if src_local is not None and len(src_local) < sh.num_shards:
                raise ValueError(
                    "source has remote shards but the runtime is "
                    "single-process — no other process exists to feed "
                    "them (launch via repro.launch.cluster)")
            return None
        lids = compat.local_shard_indices(self.mesh, self._pspec(),
                                          sh.num_shards)
        if src_local is not None:
            missing = sorted(set(lids) - set(src_local))
            if missing:
                raise ValueError(
                    f"process {compat.process_index()} owns mesh shards "
                    f"{lids} but the source holds no data for shards "
                    f"{missing} — align the data partition with the mesh "
                    "(ProcessShardedSource.for_process with the launch "
                    "process id)")
        return lids

    def _fetch(self, arr) -> np.ndarray:
        """Host value of a per-step output: plain ``np.asarray`` single-
        process; the ``process_allgather`` collective when shards span
        processes (every process then holds every shard's slice — the
        O(k·S) per-step shuffle, never the points)."""
        return compat.fetch_global(arr)

    # -- per-step sharded streaming -----------------------------------------

    def _stream_steps(self, sh: ShardedSource, rows: int):
        """Per-step global device arrays for the sharded fold: yields
        ``(pts (S·rows, d), mask (S·rows,) bool, counts (S,) np)`` with
        every shard's piece device-put into its own mesh address space.
        The transfer rides the sources' prefetch ring (``stream_device``
        with a sharded ``put``), so up to ``prefetch`` steps' DMAs are in
        flight ahead of the consumed one — the same overlap model as the
        single-device stream, per shard.

        Multi-process, each process reads (and ``device_put``s) only its
        own shards — the other entries in the piece list are ``None`` and
        the global array is assembled from local shards alone; masks and
        step counts are computed arithmetically for every shard, so all
        processes run the same step sequence in lockstep."""
        mesh, pspec = self.mesh, self._pspec()
        local = self._local_ids(sh)

        def put(step):
            pts, counts = step            # (S, rows, d) or [piece|None], (S,)
            mask = np.arange(rows)[None, :] < counts[:, None]
            g_p = compat.global_array_from_shards(mesh, pspec, list(pts))
            g_m = compat.global_array_from_shards(mesh, pspec, list(mask))
            return g_p, g_m, counts

        return stream_device(
            engine.zip_shard_blocks(sh.shards, rows, local_ids=local),
            self.prefetch, put=put)

    def _stream_steps_w(self, sh: ShardedSource, rows: int):
        """Weighted sibling of ``_stream_steps``: each step additionally
        ships the shards' per-row weight slices (padded lanes at weight
        0), yielding ``(pts, mask, w, counts)`` global arrays. No
        weighted multi-process caller exists, so non-local shards are
        rejected by ``zip_shard_blocks`` rather than half-supported."""
        mesh, pspec = self.mesh, self._pspec()
        local = self._local_ids(sh)

        def put(step):
            pts, wts, counts = step          # (S, rows, d), (S, rows), (S,)
            mask = np.arange(rows)[None, :] < counts[:, None]
            g_p = compat.global_array_from_shards(mesh, pspec, list(pts))
            g_m = compat.global_array_from_shards(mesh, pspec, list(mask))
            g_w = compat.global_array_from_shards(mesh, pspec, list(wts))
            return g_p, g_m, g_w, counts

        return stream_device(
            engine.zip_shard_blocks(sh.shards, rows, with_weights=True,
                                    local_ids=local),
            self.prefetch, put=put)

    def _replicated(self, arr) -> jnp.ndarray:
        if compat.process_count() > 1:
            # device_put to a replicated NamedSharding cannot target the
            # other processes' devices on the 0.4.x line — assemble the
            # replica set from per-local-device copies instead (the host
            # value is identical on every process by SPMD construction).
            return compat.replicated_array(self.mesh,
                                           np.asarray(arr, np.float32))
        return jax.device_put(jnp.asarray(arr, jnp.float32),
                              NamedSharding(self.mesh, P()))

    # -- jitted per-step shard_map programs (cached per program kind) -------

    def _round1_step(self, fn: BlockFn):
        key = ("round1", fn)
        if key not in self._step_cache:
            pspec = self._pspec()

            @functools.partial(compat.shard_map, mesh=self.mesh,
                               in_specs=(pspec, pspec),
                               out_specs=(pspec, pspec),
                               check_replication=False)
            def step(pts, mask):                    # local (rows, d), (rows,)
                c = fn(pts, mask)                   # (k, d)
                return c[None], jnp.any(mask)[None]

            self._step_cache[key] = jax.jit(step)
        return self._step_cache[key]

    def _round1w_step(self, fn: WeightedBlockFn):
        key = ("round1w", fn)
        if key not in self._step_cache:
            pspec = self._pspec()

            @functools.partial(compat.shard_map, mesh=self.mesh,
                               in_specs=(pspec, pspec, pspec),
                               out_specs=(pspec, pspec, pspec),
                               check_replication=False)
            def step(pts, mask, w):        # local (rows, d), (rows,), (rows,)
                c, cw = fn(pts, mask, w)   # (k, d), (k,)
                return c[None], cw[None], jnp.any(mask)[None]

            self._step_cache[key] = jax.jit(step)
        return self._step_cache[key]

    def _filter_step(self, rank: int, impl: str, chunk: int | None):
        key = ("filter", rank, impl, chunk)
        if key not in self._step_cache:
            pspec = self._pspec()

            @functools.partial(compat.shard_map, mesh=self.mesh,
                               in_specs=(pspec, pspec, pspec, P()),
                               out_specs=(pspec, pspec),
                               check_replication=False)
            def step(pts, d_blk, h_blk, c):
                # Per-shard fused filter tile (engine dispatches the
                # Pallas streamed kernel vs the jnp oracle — bitwise).
                d_blk, tops = engine.filter_tile_update(
                    pts, c, d_blk, h_blk, rank=rank, impl=impl, chunk=chunk)
                return d_blk, tops[None]

            self._step_cache[key] = jax.jit(step)
        return self._step_cache[key]

    def _pivot_step(self, rank: int):
        key = ("pivot", rank)
        if key not in self._step_cache:
            pspec = self._pspec()

            @functools.partial(compat.shard_map, mesh=self.mesh,
                               in_specs=(pspec, pspec),
                               out_specs=pspec,
                               check_replication=False)
            def step(d_blk, h_blk):
                cand = jnp.where(h_blk, d_blk, _NEG)
                r = min(rank, cand.shape[0])
                return jax.lax.top_k(cand, r)[0][None]

            self._step_cache[key] = jax.jit(step)
        return self._step_cache[key]

    # -- the Executor interface, sharded ------------------------------------

    def run_blocks(self, fn, source, *, objective: Objective | None = None):
        """Round 1 over the mesh machines: every step feeds each shard's
        next (padded, masked) block into its own address space and runs
        one shard_map of per-shard GONs. The center union is ordered
        shard-major (shard 0's blocks first) — global row order, exactly
        the sequential ``HostStreamExecutor`` union for the same blocking.
        Weighted objectives run the 3-operand sibling step, shipping the
        shards' weight slices through the same ring."""
        sh = self._sharded(source)
        rows = self.rows_for(sh)
        weighted = objective is not None and objective.weighted
        cs, vs, ws = [], [], []
        if weighted:
            step = self._round1w_step(fn)
            for pts, mask, w, _ in self._stream_steps_w(sh, rows):
                c, cw, v = step(pts, mask, w)       # (S,k,d), (S,k), (S,)
                cs.append(self._fetch(c))
                ws.append(self._fetch(cw))
                vs.append(self._fetch(v))
        else:
            step = self._round1_step(fn)
            for pts, mask, _ in self._stream_steps(sh, rows):
                c, v = step(pts, mask)              # (S, k, d), (S,)
                cs.append(self._fetch(c))
                vs.append(self._fetch(v))
        if not cs:
            raise ValueError("cannot run round 1 over an empty source")
        cent = np.stack(cs, axis=1)                 # (S, B, k, d) after swap
        val = np.stack(vs, axis=1)                  # (S, B)
        k = cent.shape[2]
        centers = jnp.asarray(cent.reshape(-1, cent.shape[-1]))   # (S·B·k, d)
        valid = jnp.asarray(np.repeat(val.reshape(-1), k))
        if weighted:
            wgt = np.stack(ws, axis=1)              # (S, B, k)
            return centers, valid, jnp.asarray(wgt.reshape(-1))
        return centers, valid

    def default_capacity(self, source, k: int) -> int:
        return max(self.rows_for(source), 2 * k)

    def radius2(self, source, centers, *, impl="auto", chunk=None,
                objective: Objective | None = None):
        """Squared covering radius over the sharded stream.

        Runs the *eager* per-block ``engine.fold_min_d2`` over the
        ``ShardedSource``'s global block stream (per-shard ``rows``, the
        prefetch ring) rather than a jitted shard_map fold: the repo-wide
        radius2 contract is the eager ``assign_nearest`` bits (Sim / the
        device EIM path / HostStream all reduce those), and XLA's fused
        jit form of the ``x²+c²−2x·c`` chain is *not* bit-identical to
        the op-by-op eager dispatch on every backend — a jitted mesh fold
        here would break the cross-executor bitwise-parity guarantee.
        Residency is unchanged: one block (plus the ring) at a time,
        bounded by the per-shard budget. Device-resident inputs keep the
        one-pass fused max."""
        src = as_source(source)
        if isinstance(src, ArraySource):
            # reprolint: disable=R002 -- ArraySource is already in HBM; materialize() is a zero-copy unwrap
            _, d2 = ops.assign_nearest(src.materialize(), centers,
                                       impl=impl, chunk=chunk)
            if _is_plain(objective):
                return jnp.max(d2)
            # Same eager d2 bits; the objective only changes the reduction
            # (top-1 of a multiset == its max, preserving unit-weight bits).
            if objective.weighted:
                w = jnp.asarray(weights_of(src, 0, src.n))
                d2 = jnp.where(w > 0, d2, _NEG)
            r = objective.outliers + 1
            top = engine.merge_top_k(engine.top_k_init(r), d2, r)
            return jnp.maximum(top[r - 1], jnp.float32(0.0))
        sh = self._sharded(src)
        local = self._local_ids(sh)
        if local is not None:
            if not _is_plain(objective):
                raise NotImplementedError(
                    "multi-process radius2 supports only the plain "
                    "objective (a top-(z+1) cross-process merge is a "
                    "value fold too, but no caller exists yet)")
            # Per-process partial max over the *local* shards (same
            # blocks, same eager fold_min_d2 bits as the global stream —
            # blocks never cross shard boundaries), then an exact
            # cross-process max merge: max is invariant to merge order,
            # so the result is bitwise the single-process fold.
            rows = self.rows_for(sh)
            best = np.float32(0.0)       # d² ≥ 0; empty shards fold to 0
            for s in local:
                part = engine.fold_min_d2(sh.shards[s], centers, impl=impl,
                                          chunk=chunk, block_rows=rows,
                                          prefetch=self.prefetch)
                best = np.maximum(best, np.asarray(part, np.float32))
            parts = compat.exchange_host(np.asarray(best, np.float32))
            return jnp.asarray(np.max(parts), jnp.float32)
        if not _is_plain(objective):
            top = engine.fold_top_k_min_d2(
                sh, centers, objective.outliers + 1, impl=impl, chunk=chunk,
                block_rows=self.rows_for(sh), prefetch=self.prefetch,
                weighted=objective.weighted)
            return jnp.maximum(top[objective.outliers], jnp.float32(0.0))
        return engine.fold_min_d2(sh, centers, impl=impl, chunk=chunk,
                                  block_rows=self.rows_for(sh),
                                  prefetch=self.prefetch)

    def run_filter_round(self, source, s_new, d_s, h_mask, rank, *,
                         impl="auto", chunk=None, weights=None):
        """EIM Rounds 2–3 over the mesh machines: each step updates every
        shard's slice of d(x, S_new) in its own address space and emits a
        per-shard top-k; the host merge of the per-shard tops is the
        MapReduce shuffle (top-k *values* are blocking-invariant, so the
        pivot is bitwise the Sim/HostStream one). ``source`` may be a
        compacted ``IndexedSource`` view — it is split into contiguous
        machine ranges on the fly; ``d_s``/``h_mask`` hold the per-view
        slices, updated in place exactly like the other executors."""
        if weights is not None:
            # Weighted EIM needs per-shard weight slices riding the state
            # ring; no weighted caller exists yet (kz_center solves on the
            # host-resident coreset), so fail loudly rather than silently
            # ignoring the weights.
            raise NotImplementedError(
                "MeshExecutor.run_filter_round does not support weights "
                "yet — use SimExecutor or HostStreamExecutor for weighted "
                "filter rounds")
        sh = self._sharded(source)
        rows = self.rows_for(sh)
        S = sh.num_shards
        have_s = s_new is not None and len(s_new) > 0
        mesh, pspec = self.mesh, self._pspec()
        local = self._local_ids(sh)
        pos = sh.offsets[:-1].astype(np.int64)      # per-shard view cursor

        def put(step_data):
            """Ring transfer: ship the step's points *and* the matching
            d/h state slices (rows are touched exactly once per call, so
            prefetching state ahead of the fold is safe)."""
            pts, counts = step_data
            starts = pos.copy()
            p_d, p_h = [], []
            for s in range(S):
                nb = int(counts[s])
                a = int(pos[s])
                dd = np.full(rows, np.float32(3.4e38), np.float32)
                dd[:nb] = d_s[a:a + nb]
                hh = np.zeros(rows, bool)
                hh[:nb] = h_mask[a:a + nb]
                p_d.append(dd)
                p_h.append(hh)
                pos[s] += nb
            return (compat.global_array_from_shards(mesh, pspec, list(pts)),
                    compat.global_array_from_shards(mesh, pspec, p_d),
                    compat.global_array_from_shards(mesh, pspec, p_h),
                    counts, starts)

        steps = stream_device(
            engine.zip_shard_blocks(sh.shards, rows, local_ids=local),
            self.prefetch, put=put)
        if have_s:
            c = self._replicated(np.asarray(s_new, np.float32))
            fstep = self._filter_step(rank, impl, chunk)
        else:
            pstep = self._pivot_step(rank)
        top = engine.top_k_init(rank)
        for g_pts, g_d, g_h, counts, starts in steps:
            if have_s:
                d_upd, tops = fstep(g_pts, g_d, g_h, c)
                # Multi-process, the fetch is an allgather: every process
                # writes back *every* shard's slice, keeping the host
                # d(x, S) relation replicated — the next iteration's state
                # pieces are then constructible everywhere.
                d_np = self._fetch(d_upd).reshape(S, rows)
                for s in range(S):
                    nb = int(counts[s])
                    a = int(starts[s])
                    d_s[a:a + nb] = d_np[s, :nb]
            else:
                tops = pstep(g_d, g_h)
            top = engine.merge_top_k(top, jnp.asarray(self._fetch(tops)),
                                     rank)
        return d_s, _pivot_from_top(top, rank)

    # -- MRG: fused device program, or the streamed sharded orchestration ---

    def mrg(self, source, k: int, *, capacity: int | None = None,
            impl: str = "auto", chunk: int | None = None,
            objective: Objective | None = None):
        """MRG on the mesh. Device-resident inputs (raw arrays /
        ``ArraySource``) run the fused shard_map program (capacity is
        fixed by the mesh blocking there — ``capacity=`` raises);
        sharded / host-backed sources run the streamed per-shard rounds
        with the shared Lemma-3 ``combine`` (``capacity`` honored).
        Non-plain objectives always take the streamed orchestration —
        the fused program has no weight operand, and grafting one in
        would recompile (and risk perturbing) the plain device path."""
        src = as_source(source)
        if isinstance(src, ArraySource) and _is_plain(objective):
            if capacity is not None:
                raise ValueError(
                    "MeshExecutor's machine capacity on the device path is "
                    "fixed by the mesh blocking (shard size / gather "
                    "tree); capacity= is not supported — use shard_axes/"
                    "hierarchical, or pass a ShardedSource / host-backed "
                    "source for the streamed path")
            return self._mrg_fused(src, k, impl=impl, chunk=chunk)
        return super().mrg(src, k, capacity=capacity, impl=impl, chunk=chunk,
                           objective=objective)

    def _mrg_fused(self, source, k: int, *, impl: str = "auto",
                   chunk: int | None = None):
        axes = self.shard_axes
        hierarchical = self.hierarchical
        pspec = self._pspec()

        @functools.partial(
            compat.shard_map,
            mesh=self.mesh,
            in_specs=(pspec,),
            out_specs=(P(), P()),
            check_replication=False,
        )
        def run(local):
            res = gonzalez(local, k, impl=impl, chunk=chunk)
            centers = res.centers
            if hierarchical and len(axes) > 1:
                for ax in axes:
                    centers = jax.lax.all_gather(centers, ax, tiled=True)
                    centers = gonzalez(centers, k, impl=impl,
                                       chunk=chunk).centers
            else:
                for ax in axes:
                    centers = jax.lax.all_gather(centers, ax, tiled=True)
                centers = gonzalez(centers, k, impl=impl, chunk=chunk).centers
            # local covering radius -> global max
            _, d2 = ops.assign_nearest(local, centers, impl=impl, chunk=chunk)
            r2 = jnp.max(d2)
            for ax in axes:
                r2 = jax.lax.pmax(r2, ax)
            return centers, r2

        x = as_source(source).materialize()
        sharding = NamedSharding(self.mesh, pspec)
        x = jax.device_put(x, sharding)
        centers, r2 = run(x)
        rounds = 1 + (len(axes) if hierarchical and len(axes) > 1 else 1)
        return centers, r2, rounds
