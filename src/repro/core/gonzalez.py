"""Gonzalez's greedy 2-approximation for k-center (paper §3.1, "GON").

Algorithm: pick an arbitrary first center; repeatedly pick the point
farthest from the chosen set until k centers are selected. The triangle
inequality gives a factor-2 guarantee (Gonzalez 1985).

TPU/JAX adaptation (DESIGN.md §2): the k-loop is inherently sequential but
each iteration is a fully-parallel fused pass over all n points
(distance-to-new-center + running-min update + arg-farthest). That pass is
the compute hot-spot and is served by ``repro.kernels`` (Pallas on TPU,
jnp elsewhere). The loop itself is ``lax.fori_loop``, so the whole
algorithm is one XLA program — jit/vmap/shard_map composable, which is
what MRG builds on.

"Arbitrary" choices are pinned for determinism across restarts: the first
center defaults to the first (valid) point.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.source import ArraySource, is_source
from repro.kernels import ops

_NEG = np.float32(-3.4e38)  # sentinel: masked-out points can never be farthest


class GonzalezResult(NamedTuple):
    centers: jnp.ndarray   # (k, d) selected center coordinates
    indices: jnp.ndarray   # (k,)  int32 indices into the input
    radius2: jnp.ndarray   # ()    squared covering radius over valid points
    min_d2: jnp.ndarray    # (n,)  final per-point squared distance to centers
                           #       (host numpy on the out-of-core source path)


def gonzalez(
    points,
    k: int,
    *,
    mask: jnp.ndarray | None = None,
    first: int | jnp.ndarray = 0,
    impl: str = "auto",
    chunk: int | None = None,
    block_rows: int | None = None,
    memory_budget: int | None = None,
) -> GonzalezResult:
    """Run GON on ``points (n,d)``; optionally restricted to ``mask (n,) bool``.

    ``points`` may also be any ``repro.data.source.PointSource``: a device
    ``ArraySource`` runs the jitted in-memory algorithm unchanged, while
    host/disk/generator sources run the out-of-core form — each of the k
    passes streams the source block-by-block (``block_rows`` /
    ``memory_budget``, see kernels/engine.py) with at most two blocks
    device-resident (double-buffered DMA); the per-point distance state
    lives on the host. The
    selected centers and radius are identical to the in-memory run
    (tests/test_sources.py). ``mask`` is not supported for streamed
    sources.

    With a mask, invalid points are never selected as centers and are
    excluded from the covering radius. If fewer than ``k`` valid points
    exist, the remaining center slots repeat already-covered points
    (radius is unaffected). ``k`` is static.

    ``chunk`` (static) streams each fused pass in row-blocks of at most
    ``chunk`` points (O(chunk·d) working set per step instead of O(n·d)
    transients) — the selected centers and radius are invariant to it
    (tests/test_engine.py).

    Returns a ``GonzalezResult`` ``(centers (k, d), indices (k,) i32,
    radius2 (), min_d2 (n,))``; ``radius2`` is the exact squared fold
    ``max(min_d2)`` (no lossy sqrt round-trip), identical across the
    in-memory, chunked and streamed forms.

    >>> import numpy as np
    >>> x = np.asarray([[0, 0], [1, 0], [10, 0], [10, 1]], np.float32)
    >>> res = gonzalez(x, 2)       # first center = row 0, then farthest
    >>> [int(i) for i in res.indices]
    [0, 3]
    >>> float(res.radius2)
    1.0
    """
    if is_source(points):
        if isinstance(points, ArraySource):
            # reprolint: disable=R002 -- ArraySource is already device-resident; zero-copy unwrap
            points = points.materialize()
        else:
            if mask is not None:
                raise ValueError(
                    "mask is not supported for streamed PointSources")
            return _gonzalez_source(points, k, first=int(first), impl=impl,
                                    chunk=chunk, block_rows=block_rows,
                                    memory_budget=memory_budget)
    return _gonzalez_device(points, k, mask=mask, first=first, impl=impl,
                            chunk=chunk)


@functools.partial(jax.jit, static_argnames=("k", "impl", "chunk"))
def _gonzalez_device(
    points: jnp.ndarray,
    k: int,
    *,
    mask: jnp.ndarray | None = None,
    first: int | jnp.ndarray = 0,
    impl: str = "auto",
    chunk: int | None = None,
) -> GonzalezResult:
    """The jitted in-memory algorithm (see ``gonzalez``)."""
    n, d = points.shape
    points = points.astype(jnp.float32)
    if mask is None:
        first_idx = jnp.asarray(first, jnp.int32)
    else:
        # first valid point (ignores `first` when a mask is given)
        first_idx = jnp.argmax(mask).astype(jnp.int32)

    c0 = points[first_idx]
    min_d2 = ops.dist2_to_center(points, c0, impl=impl)
    if mask is not None:
        min_d2 = jnp.where(mask, min_d2, _NEG)

    centers0 = jnp.zeros((k, d), jnp.float32).at[0].set(c0)
    indices0 = jnp.zeros((k,), jnp.int32).at[0].set(first_idx)

    def body(i, carry):
        min_d2, centers, indices = carry
        nxt = jnp.argmax(min_d2).astype(jnp.int32)
        c = points[nxt]
        new_md, _, _ = ops.fused_min_argmax(points, c, min_d2, impl=impl,
                                            chunk=chunk)
        return new_md, centers.at[i].set(c), indices.at[i].set(nxt)

    min_d2, centers, indices = jax.lax.fori_loop(
        1, k, body, (min_d2, centers0, indices0)
    )
    radius2 = jnp.max(jnp.where(min_d2 <= _NEG / 2, 0.0, min_d2))
    # masked-out points carry _NEG; clamp them to 0 for the covered-distance
    # vector we hand back.
    return GonzalezResult(centers, indices, radius2, jnp.maximum(min_d2, 0.0))


def _source_row(source, idx: int, rows: int) -> np.ndarray:
    """Row ``idx`` of a source — random access when the source offers it
    (every built-in source does), else by streaming host blocks up to it."""
    if not 0 <= idx < source.n:
        raise IndexError(f"row {idx} out of range for n={source.n}")
    if hasattr(source, "row"):
        return np.asarray(source.row(idx), np.float32)
    blocks = (source.host_blocks(rows) if hasattr(source, "host_blocks")
              else source.blocks(rows))
    off = 0
    for blk in blocks:
        if idx < off + blk.shape[0]:
            return np.asarray(blk[idx - off], np.float32)
        off += blk.shape[0]
    raise IndexError(f"source exhausted before row {idx}")  # pragma: no cover


def _gonzalez_source(source, k: int, *, first: int = 0, impl: str = "auto",
                     chunk: int | None = None, block_rows: int | None = None,
                     memory_budget: int | None = None) -> GonzalezResult:
    """Out-of-core GON: k streamed passes over a PointSource.

    Each pass folds ``fused_min_argmax`` over the source's blocks — the
    update of the running per-point min-distance and the arg-farthest
    search for the *next* center happen in the same pass, so selecting k
    centers costs k passes (k·n/block DMAs), exactly the in-memory
    algorithm's k fused passes with the n axis folded.

    Device residency: at most two blocks (double-buffered DMA) plus the
    current center. The per-point min-distance state (n floats) lives on
    the host — n is bounded by host RAM, not HBM. Tie-breaking matches the
    chunked engine (first occurrence), so centers, indices and radius are
    identical to the in-memory run.
    """
    n, d = source.n, source.d
    rows = ops.resolve_block_rows(n, d, block_rows=block_rows,
                                  memory_budget=memory_budget)
    centers = np.zeros((k, d), np.float32)
    indices = np.zeros((k,), np.int32)
    c0 = _source_row(source, first, rows)
    centers[0] = c0
    indices[0] = first

    # Pass 0: distances to the first center; track the farthest point
    # (value, global index, coordinates) — the next center.
    md_blocks: list[np.ndarray] = []
    cj = jnp.asarray(c0)
    best_v, best_i, best_row = -np.inf, first, c0
    off = 0
    for blk in source.blocks(rows):
        d2 = ops.dist2_to_center(blk, cj, impl=impl)
        bi = int(jnp.argmax(d2))
        bv = float(d2[bi])
        if bv > best_v:  # strict: earliest block wins ties, like jnp.argmax
            best_v, best_i, best_row = bv, off + bi, np.asarray(blk[bi])
        md_blocks.append(np.asarray(d2))
        off += blk.shape[0]
    radius2 = max(best_v, 0.0)

    for i in range(1, k):
        centers[i] = best_row
        indices[i] = best_i
        cj = jnp.asarray(best_row)
        best_v, nxt_i, nxt_row = -np.inf, 0, best_row
        off = 0
        for b, blk in enumerate(source.blocks(rows)):
            new_md, v, bi = ops.fused_min_argmax(
                blk, cj, jnp.asarray(md_blocks[b]), impl=impl, chunk=chunk)
            md_blocks[b] = np.asarray(new_md)
            v = float(v)
            if v > best_v:
                best_v = v
                nxt_i = off + int(bi)
                nxt_row = np.asarray(blk[int(bi)])
            off += blk.shape[0]
        radius2 = max(best_v, 0.0)
        best_i, best_row = nxt_i, nxt_row

    min_d2 = (np.maximum(np.concatenate(md_blocks), 0.0)
              if md_blocks else np.zeros((0,), np.float32))
    return GonzalezResult(jnp.asarray(centers), jnp.asarray(indices),
                          jnp.float32(radius2), min_d2)


def covering_radius(points, centers: jnp.ndarray,
                    *, mask: jnp.ndarray | None = None,
                    impl: str = "auto",
                    chunk: int | None = None,
                    block_rows: int | None = None,
                    memory_budget: int | None = None) -> jnp.ndarray:
    """Euclidean covering radius of ``centers`` over (masked) ``points``.

    ``points`` may be a ``PointSource``; streamed sources fold the radius
    block-by-block (``ops.fold_min_d2``) so the input never materializes
    on device. ``mask`` is not supported for streamed sources.
    """
    if is_source(points):
        if isinstance(points, ArraySource):
            # reprolint: disable=R002 -- ArraySource is already device-resident; zero-copy unwrap
            points = points.materialize()
        else:
            if mask is not None:
                raise ValueError(
                    "mask is not supported for streamed PointSources")
            return jnp.sqrt(ops.fold_min_d2(points, centers, impl=impl,
                                            chunk=chunk,
                                            block_rows=block_rows,
                                            memory_budget=memory_budget))
    _, d2 = ops.assign_nearest(points, centers, impl=impl, chunk=chunk)
    if mask is not None:
        d2 = jnp.where(mask, d2, 0.0)
    return jnp.sqrt(jnp.max(d2))
