"""Gonzalez's greedy 2-approximation for k-center (paper §3.1, "GON").

Algorithm: pick an arbitrary first center; repeatedly pick the point
farthest from the chosen set until k centers are selected. The triangle
inequality gives a factor-2 guarantee (Gonzalez 1985).

TPU/JAX adaptation (DESIGN.md §2): the k-loop is inherently sequential but
each iteration is a fully-parallel fused pass over all n points
(distance-to-new-center + running-min update + arg-farthest). That pass is
the compute hot-spot and is served by ``repro.kernels`` (Pallas on TPU,
jnp elsewhere). The loop itself is ``lax.fori_loop``, so the whole
algorithm is one XLA program — jit/vmap/shard_map composable, which is
what MRG builds on.

"Arbitrary" choices are pinned for determinism across restarts: the first
center defaults to the first (valid) point.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

_NEG = jnp.float32(-3.4e38)  # sentinel: masked-out points can never be farthest


class GonzalezResult(NamedTuple):
    centers: jnp.ndarray   # (k, d) selected center coordinates
    indices: jnp.ndarray   # (k,)  int32 indices into the input
    radius2: jnp.ndarray   # ()    squared covering radius over valid points
    min_d2: jnp.ndarray    # (n,)  final per-point squared distance to centers


@functools.partial(jax.jit, static_argnames=("k", "impl", "chunk"))
def gonzalez(
    points: jnp.ndarray,
    k: int,
    *,
    mask: jnp.ndarray | None = None,
    first: int | jnp.ndarray = 0,
    impl: str = "auto",
    chunk: int | None = None,
) -> GonzalezResult:
    """Run GON on ``points (n,d)``; optionally restricted to ``mask (n,) bool``.

    With a mask, invalid points are never selected as centers and are
    excluded from the covering radius. If fewer than ``k`` valid points
    exist, the remaining center slots repeat already-covered points
    (radius is unaffected). ``k`` is static.

    ``chunk`` (static) streams each fused pass in row-blocks of at most
    ``chunk`` points (O(chunk·d) working set per step instead of O(n·d)
    transients) — the selected centers and radius are invariant to it
    (tests/test_engine.py).
    """
    n, d = points.shape
    points = points.astype(jnp.float32)
    if mask is None:
        first_idx = jnp.asarray(first, jnp.int32)
    else:
        # first valid point (ignores `first` when a mask is given)
        first_idx = jnp.argmax(mask).astype(jnp.int32)

    c0 = points[first_idx]
    min_d2 = ops.dist2_to_center(points, c0, impl=impl)
    if mask is not None:
        min_d2 = jnp.where(mask, min_d2, _NEG)

    centers0 = jnp.zeros((k, d), jnp.float32).at[0].set(c0)
    indices0 = jnp.zeros((k,), jnp.int32).at[0].set(first_idx)

    def body(i, carry):
        min_d2, centers, indices = carry
        nxt = jnp.argmax(min_d2).astype(jnp.int32)
        c = points[nxt]
        new_md, _, _ = ops.fused_min_argmax(points, c, min_d2, impl=impl,
                                            chunk=chunk)
        return new_md, centers.at[i].set(c), indices.at[i].set(nxt)

    min_d2, centers, indices = jax.lax.fori_loop(
        1, k, body, (min_d2, centers0, indices0)
    )
    radius2 = jnp.max(jnp.where(min_d2 <= _NEG / 2, 0.0, min_d2))
    # masked-out points carry _NEG; clamp them to 0 for the covered-distance
    # vector we hand back.
    return GonzalezResult(centers, indices, radius2, jnp.maximum(min_d2, 0.0))


def covering_radius(points: jnp.ndarray, centers: jnp.ndarray,
                    *, mask: jnp.ndarray | None = None,
                    impl: str = "auto",
                    chunk: int | None = None) -> jnp.ndarray:
    """Euclidean covering radius of ``centers`` over (masked) ``points``."""
    _, d2 = ops.assign_nearest(points, centers, impl=impl, chunk=chunk)
    if mask is not None:
        d2 = jnp.where(mask, d2, 0.0)
    return jnp.sqrt(jnp.max(d2))
