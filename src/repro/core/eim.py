"""EIM — parameterized iterative-sampling k-center (paper §4, Algorithms 2–3).

Re-implementation of Ene/Im/Moseley's MapReduce sampling scheme with the
paper's two modifications:

  * **Termination fix** (paper §4.1): points sampled into S are *always*
    removed from R, and the removal test is ``d(x,S) <= d(v,S)`` (ties
    removed), so |R| strictly decreases and the loop cannot stall.
  * **φ parameter** (paper §4.2 / Algorithm 3): the pivot v is the
    ``φ·log n``-th farthest point of H from S (original scheme: φ = 8).
    φ > 5.15 keeps the 10-approximation w.s.p. (paper §6); smaller φ
    trades the guarantee for fewer/cheaper iterations.

Two execution forms share one algorithm (and are **bitwise identical** on
the ref backend for the same key):

  * **Device fast path** (raw arrays / ``ArraySource``): MapReduce's
    shrinking relations R, S, H become masks over a fixed (n, d) array —
    XLA needs static shapes, so "remove from R" clears a mask bit. The
    loop is a ``lax.while_loop``; per-iteration sampled sets land in
    fixed-capacity index buffers (expected |S_new| = 9k·n^ε·log n with 3σ
    Poisson headroom; overflow beyond capacity is dropped and counted —
    a <1e-6-probability event that only slows convergence).
  * **Streamed source path** (host / disk / generator sources, or any
    explicit ``executor=``): the MapReduce-native formulation — R, S, H
    are host-resident per-point state (O(n) bools/floats, tiny next to the
    (n, d) points), and every per-iteration pass is a fold over a
    ``PointSource`` via ``Executor.run_filter_round``, mirroring how
    ``gonzalez`` streams. The iteration maps onto the paper's rounds:
    Round 1 (independent sampling) needs *no data pass at all* — the
    Bernoulli draws are counter-based per global row
    (``engine.bernoulli_rows``, Philox keyed by absolute row index, so
    the sampled sets are invariant to blocking, the same trick
    ``SyntheticSource("unif")`` uses) and the sampled coordinates are
    fetched by ``source.take``; Rounds 2–3 (Select + filter) are one
    streamed fold (masked incremental-min ``d(x, S_new)`` through
    ``assign_nearest`` + a cross-block top-k merge for the φ·log n
    pivot). The final "send C to one machine" GON round compacts the
    sample through ``source.take`` — all of n is never device-resident.

The loop runs while ``|R| > (4/ε)·k·n^ε·log n`` (+ an iteration cap as a
safety net; the paper proves O(1/ε) iterations w.h.p. and observes ≤ 2 in
practice). Both paths evaluate the condition, the sampling probabilities
and every distance comparison in f32 with identical expressions, which is
what makes the parity bitwise rather than approximate.

``impl`` reaches every distance pass of both forms: the executors' filter
rounds dispatch through ``engine.filter_tile_update`` /
``engine.eim_filter_block``, so on backends with a native Pallas lowering
(``impl="auto"`` on TPU, feature-detected GPU) Rounds 2–3 run as the fused
one-VMEM-pass streamed tile (``kernels/fused_stream.py``) — bitwise the
ref oracle, as the parity suite pins in interpret mode on CPU.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.data.source import (ArraySource, IndexedSource, as_device_array,
                               as_source, is_source)
from repro.kernels import engine, ops

from .executor import Executor, HostStreamExecutor
from .gonzalez import gonzalez

_NEG = np.float32(-3.4e38)
_BIG = np.float32(3.4e38)


class EIMSample(NamedTuple):
    sample_mask: jnp.ndarray   # (n,) bool — C = S ∪ R_final (numpy on the
                               #     streamed path: host-resident relations)
    s_mask: jnp.ndarray        # (n,) bool — sampled centers S
    iters: jnp.ndarray         # ()   int32 — loop iterations used
    overflow: jnp.ndarray      # ()   int32 — samples dropped by buffer caps
    sampled: jnp.ndarray       # ()   bool  — False => loop never ran (EIM≡GON)


class EIMResult(NamedTuple):
    centers: jnp.ndarray       # (k, d)
    radius2: jnp.ndarray       # ()
    sample: EIMSample


def _expected_caps(n: int, k: int, eps: float, slack: float = 3.0):
    """Fixed buffer capacities with Poisson 3σ-ish headroom."""
    ln_n = math.log(max(n, 2))
    es = 9.0 * k * (n ** eps) * ln_n
    eh = 4.0 * (n ** eps) * ln_n
    s_cap = int(min(n, math.ceil(es + slack * math.sqrt(es) + 16)))
    h_cap = int(min(n, math.ceil(eh + slack * math.sqrt(eh) + 16)))
    return s_cap, h_cap


def _params(n: int, k: int, eps: float, phi: float):
    """Shared schedule: (ln n, |R| threshold, s_cap, h_cap, pivot rank,
    S-sample numerator, H-sample numerator)."""
    ln_n = math.log(max(n, 2))
    threshold = (4.0 / eps) * k * (n ** eps) * ln_n
    s_cap, h_cap = _expected_caps(n, k, eps)
    # Select(): pivot rank φ·log n (>=1), clipped to the H buffer.
    rank = max(1, min(h_cap, int(round(phi * ln_n))))
    num_s = 9.0 * k * (n ** eps) * ln_n
    num_h = 4.0 * (n ** eps) * ln_n
    return ln_n, threshold, s_cap, h_cap, rank, num_s, num_h


def _sample_cap(n: int, k: int, eps: float, s_count: int) -> int:
    """The §4 bound on the compacted sample: |C| = |R_final| + |S| with
    |R_final| <= (4/ε)k·n^ε·log n at loop exit."""
    ln_n = math.log(max(n, 2))
    return int(min(n, math.ceil((4.0 / eps) * k * (n ** eps) * ln_n)
                   + s_count))


def _check_sample_cap(pop: int, s_count: int, n: int, k: int, eps: float,
                      max_iters: int) -> None:
    cap = _sample_cap(n, k, eps, s_count)
    if pop > cap:
        raise RuntimeError(
            f"EIM sample overflow: |C| = {pop} exceeds the paper-§4 bound "
            f"(4/ε)k·n^ε·log n + |S| = {cap} — the sampling loop hit "
            f"max_iters={max_iters} before |R| fell under the threshold. "
            f"Raise max_iters (or φ) instead of truncating the sample.")


def _compact_cap(pop: int, n: int) -> int:
    """Shape-stable gather capacity for the final GON: |C| rounded up to
    the next power of two (capped at n), so repeated ``eim`` calls re-jit
    the compact GON only per size bucket, never per exact |C|."""
    cap = 1
    while cap < pop:
        cap <<= 1
    return min(cap, n)


def _compact_gonzalez(pts_np: np.ndarray, pop: int, cap: int, k: int, *,
                      impl: str, chunk: int | None):
    """GON over the compacted sample, padded to ``cap`` rows with a
    validity mask (padding can never be selected or affect the radius —
    and both EIM paths pick identical centers for identical valid rows)."""
    d = pts_np.shape[1]
    if cap > pop:
        pts_np = np.concatenate(
            [pts_np, np.zeros((cap - pop, d), np.float32)])
    valid = np.zeros(cap, bool)
    valid[:pop] = True
    return gonzalez(jnp.asarray(pts_np), k, mask=jnp.asarray(valid),
                    impl=impl, chunk=chunk)


# ---------------------------------------------------------------------------
# public API — dispatch between the device fast path and the streamed loop
# (raw arrays / ArraySource keep the legacy device path, mirroring ``mrg``'s
# rule: only an explicit non-device PointSource — or an explicit executor —
# opts into streaming)
# ---------------------------------------------------------------------------

def _check_compact_threshold(compact_threshold: float) -> float:
    if not 0.0 <= compact_threshold <= 1.0:
        raise ValueError(
            f"compact_threshold must be in [0, 1], got {compact_threshold} "
            "(0 = never compact, 1 = compact whenever R shrank)")
    return float(compact_threshold)


def eim_sample(
    points,
    k: int,
    key: jax.Array,
    *,
    eps: float = 0.1,
    phi: float = 8.0,
    max_iters: int = 64,
    impl: str = "auto",
    chunk: int | None = None,
    executor: Executor | None = None,
    compact_threshold: float = 0.5,
) -> EIMSample:
    """Algorithm 2 (EIM-MapReduce-Sample) with the φ-parameterized Select.

    ``points`` is anything ``as_source`` accepts. Raw arrays and
    ``ArraySource`` run the jitted device fast path; host / disk /
    generator sources (or any call with an explicit ``executor=``) run the
    streamed out-of-core loop — per-point state on the host, every pass a
    fold over the source (``HostStreamExecutor`` by default; its
    ``memory_budget`` bounds device residency). Both paths draw from the
    same counter-based per-row sampler, so for the same ``key`` the
    returned sample is bitwise identical on the ref backend regardless of
    path or blocking.

    ``compact_threshold`` (streamed path only) controls the shrinking-|R|
    iteration cost (paper §4: Round 3 is charged O(|R_l|·|S_new|/m), not
    O(n·|S_new|)): when the surviving |R| falls under this fraction of the
    current view, the fold is re-pointed at an ``IndexedSource`` of the
    survivors, so later passes touch |R| rows instead of n. ``0`` never
    compacts, ``1`` compacts after every shrinking iteration; the sampled
    sets are bitwise invariant to the choice (Round-1 draws are keyed by
    *original* absolute row index — ``engine.bernoulli_rows_at`` — and
    the d(x,S)/pivot folds are per-row/value reductions). The device fast
    path has no views (masks over a fixed array) and ignores the knob.

    ``chunk`` streams the per-iteration distance update in row-blocks
    (kernels/engine.py memory model) — the sample is unchanged: the PRNG
    stream is identical and, for inputs whose coordinates are far below
    the 1e18 invalid-slot sentinel, so is every distance the loop compares.
    """
    compact_threshold = _check_compact_threshold(compact_threshold)
    streamed = is_source(points) and not isinstance(points, ArraySource)
    if not streamed and executor is None:
        return _eim_sample_device(as_device_array(points), k, key, eps=eps,
                                  phi=phi, max_iters=max_iters, impl=impl,
                                  chunk=chunk)
    source = as_source(points)
    if executor is None:
        executor = HostStreamExecutor()
    return _eim_sample_stream(source, k, key, eps=eps, phi=phi,
                              max_iters=max_iters, executor=executor,
                              impl=impl, chunk=chunk,
                              compact_threshold=compact_threshold)


def eim(
    points,
    k: int,
    key: jax.Array,
    *,
    eps: float = 0.1,
    phi: float = 8.0,
    max_iters: int = 64,
    impl: str = "auto",
    chunk: int | None = None,
    compact: bool = True,
    executor: Executor | None = None,
    compact_threshold: float = 0.5,
) -> EIMResult:
    """Full EIM: sample, then run GON on the sample (final MapReduce round).

    With ``compact=True`` the sample is gathered into a dense ``|C|``-row
    buffer before the final GON — the "send S ∪ R to one machine" round;
    the final GON then costs O(k·|C|) instead of O(k·n). |C| is checked
    against the paper's §4 bound ``(4/ε)k·n^ε·log n + |S|`` (with the
    realized |S|) and a ``RuntimeError`` is raised if the loop failed to
    converge within ``max_iters`` — never a silent truncation.

    Streamed sources compact through ``source.take`` (random-access
    gather), so the full (n, d) array is never device-resident; the
    covering radius is the executor's streamed fold. ``compact=False``
    (GON over the masked full array) is device-path only.
    ``compact_threshold`` is the streamed loop's shrinking-|R| knob (see
    ``eim_sample``) — unrelated to ``compact``, which is about the *final*
    GON round.

    Returns an ``EIMResult`` ``(centers (k, d), radius2 (), sample)``;
    ``sample.sampled`` is False when n is too small for the sampling
    regime to engage (the loop guard ``|R| > (4/ε)k·n^ε·log n`` — then
    EIM degenerates to GON, as the paper observes for large k):

    >>> import numpy as np, jax
    >>> x = np.random.default_rng(0).normal(size=(512, 3)).astype(np.float32)
    >>> res = eim(x, 8, jax.random.PRNGKey(1))
    >>> res.centers.shape
    (8, 3)
    >>> bool(res.sample.sampled)   # n = 512 is below the sampling regime
    False
    """
    compact_threshold = _check_compact_threshold(compact_threshold)
    streamed = is_source(points) and not isinstance(points, ArraySource)
    if not streamed and executor is None:
        return _eim_device(points, k, key, eps=eps, phi=phi,
                           max_iters=max_iters, impl=impl, chunk=chunk,
                           compact=compact)
    if not compact:
        raise ValueError(
            "compact=False runs GON over the masked full array and needs "
            "it device-resident; streamed EIM always compacts via "
            "source.take")
    source = as_source(points)
    if executor is None:
        executor = HostStreamExecutor()
    sample = _eim_sample_stream(source, k, key, eps=eps, phi=phi,
                                max_iters=max_iters, executor=executor,
                                impl=impl, chunk=chunk,
                                compact_threshold=compact_threshold)
    idx = np.nonzero(np.asarray(sample.sample_mask))[0]
    pop = len(idx)
    _check_sample_cap(pop, int(np.asarray(sample.s_mask).sum()),
                      source.n, k, eps, max_iters)
    if pop == source.n:
        # EIM ≡ GON (the loop never engaged): stream GON over the source
        # instead of gathering all of n — the out-of-core contract holds
        # even in the degenerate regime.
        res = gonzalez(source, k, impl=impl, chunk=chunk,
                       block_rows=(executor.rows_for(source)
                                   if hasattr(executor, "rows_for")
                                   else None))
    else:
        # Final round: C is compacted to one machine by random-access
        # gather — O(|C|) rows move, never the full source.
        res = _compact_gonzalez(source.take(idx), pop,
                                _compact_cap(pop, source.n), k,
                                impl=impl, chunk=chunk)
    r2 = executor.radius2(source, res.centers, impl=impl, chunk=chunk)
    return EIMResult(res.centers, r2, sample)


# ---------------------------------------------------------------------------
# device fast path — masks over a fixed (n, d) array, one lax.while_loop
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("k", "eps", "phi", "max_iters", "impl", "chunk")
)
def _eim_sample_device(
    points: jnp.ndarray,
    k: int,
    key: jax.Array,
    *,
    eps: float = 0.1,
    phi: float = 8.0,
    max_iters: int = 64,
    impl: str = "auto",
    chunk: int | None = None,
) -> EIMSample:
    n, d = points.shape
    points = points.astype(jnp.float32)
    _, threshold, s_cap, _, rank, num_s, num_h = _params(n, k, eps, phi)

    def cond(state):
        r_mask, s_mask, d_s, key, it, ovf = state
        # f32 compare, mirrored exactly by the streamed loop's host check.
        return ((jnp.sum(r_mask).astype(jnp.float32)
                 > jnp.float32(threshold)) & (it < max_iters))

    def body(state):
        r_mask, s_mask, d_s, key, it, ovf = state
        keys = jax.random.split(key, 3)
        key, k_s, k_h = keys[0], keys[1], keys[2]
        r_size = jnp.sum(r_mask).astype(jnp.float32)

        # --- Round 1: independent sampling within R (Alg. 2, lines 3-4) ---
        # Counter-based draws (Philox over the absolute row index): the
        # same f32 probabilities and per-row stream as the out-of-core
        # path, so the two paths sample identical sets.
        p_s = jnp.minimum(jnp.float32(num_s) / r_size, jnp.float32(1.0))
        p_h = jnp.minimum(jnp.float32(num_h) / r_size, jnp.float32(1.0))
        new_s = engine.bernoulli_rows(k_s, 0, n, p_s) & r_mask
        h_mask = engine.bernoulli_rows(k_h, 0, n, p_h) & r_mask

        # Materialize new S members into a fixed buffer (gather indices).
        s_idx = jnp.nonzero(new_s, size=s_cap, fill_value=n)[0]
        s_valid = s_idx < n
        ovf = ovf + (jnp.sum(new_s) - jnp.sum(s_valid)).astype(jnp.int32)
        s_pts = points[jnp.minimum(s_idx, n - 1)]           # (s_cap, d)

        # Incremental d(x, S) update: distances to the *new* members only
        # (the paper's Round-3 O(|R|·|S|/m) term). Invalid buffer slots are
        # moved to a far-away coordinate so they never win the min; routing
        # through assign_nearest (a fused min-reduction) instead of
        # pairwise keeps the chunked peak at O(chunk·s_cap) — a chunked
        # pairwise would still stack the full (n, s_cap) block. The update
        # is gated on having any valid sample so a zero-sample iteration
        # leaves the uncovered-point sentinel (_BIG) exactly untouched.
        far_pts = jnp.where(s_valid[:, None], s_pts, 1e18)
        _, d_new = ops.assign_nearest(points, far_pts, impl=impl,
                                      chunk=chunk)            # (n,)
        d_s = jnp.where(jnp.any(s_valid), jnp.minimum(d_s, d_new), d_s)

        s_mask = s_mask | new_s
        # Termination fix (paper §4.1): sampled points always leave R.
        r_mask = r_mask & ~new_s

        # --- Round 2: Select(H, S) (Alg. 3) ----------------------------
        d_h = jnp.where(h_mask, d_s, _NEG)
        top = jax.lax.top_k(d_h, rank)[0]
        pivot = top[rank - 1]                                # d(v, S)^2
        # If H had fewer than `rank` valid points, pivot is _NEG: no
        # distance-based removals this iteration (sampling still shrinks R).
        pivot = jnp.where(pivot <= _NEG / 2, -1.0, pivot)

        # --- Round 3: filter R (Alg. 2, lines 7-8) ----------------------
        r_mask = r_mask & ~(d_s <= pivot)
        return r_mask, s_mask, d_s, key, it + 1, ovf

    r0 = jnp.ones((n,), bool)
    s0 = jnp.zeros((n,), bool)
    d0 = jnp.full((n,), _BIG)
    sampled = jnp.asarray(n > threshold)
    r_mask, s_mask, _, _, iters, ovf = jax.lax.while_loop(
        cond, body, (r0, s0, d0, key, jnp.int32(0), jnp.int32(0))
    )
    return EIMSample(r_mask | s_mask, s_mask, iters, ovf, sampled)


def _eim_device(points, k, key, *, eps, phi, max_iters, impl, chunk,
                compact):
    """Device-path eim(): jitted sample + host-side compaction."""
    points = as_device_array(points)
    n, d = points.shape
    sample = _eim_sample_device(points, k, key, eps=eps, phi=phi,
                                max_iters=max_iters, impl=impl, chunk=chunk)
    if compact:
        idx = np.nonzero(np.asarray(sample.sample_mask))[0]
        pop = len(idx)
        _check_sample_cap(pop, int(np.asarray(sample.s_mask).sum()),
                          n, k, eps, max_iters)
        pts = np.asarray(points[jnp.asarray(idx, jnp.int32)])
        res = _compact_gonzalez(pts, pop, _compact_cap(pop, n), k,
                                impl=impl, chunk=chunk)
    else:
        res = gonzalez(points, k, mask=sample.sample_mask, impl=impl,
                       chunk=chunk)
    # Squared fold directly — the sqrt→square round-trip of
    # ``covering_radius`` is lossy in f32 and must match the executors'
    # ``radius2`` bitwise (cross-path parity tests compare these).
    _, d2 = ops.assign_nearest(points, res.centers, impl=impl, chunk=chunk)
    return EIMResult(res.centers, jnp.max(d2), sample)


# ---------------------------------------------------------------------------
# streamed source path — host-driven iterations over Executor.run_filter_round
# ---------------------------------------------------------------------------

def _eim_sample_stream(source, k: int, key, *, eps: float, phi: float,
                       max_iters: int, executor: Executor,
                       impl: str = "auto",
                       chunk: int | None = None,
                       compact_threshold: float = 0.5) -> EIMSample:
    """Out-of-core Algorithm 2: the MapReduce-native form.

    Per-point relations live on the host (``r_mask``, ``s_mask`` bools and
    ``d_s`` f32 — O(n) bytes); the (n, d) points stay wherever the source
    keeps them. Each iteration is:

      * Round 1 — sampling needs **no pass over the data**: the Bernoulli
        decision for global row i is a pure function of (iteration key, i)
        (``engine.bernoulli_rows`` / the gather-form
        ``engine.bernoulli_rows_at`` once the relation is compacted),
        evaluated here in index blocks; only the |S_new| sampled
        coordinates are fetched, by ``source.take``.
      * Rounds 2–3 — one streamed fold (``executor.run_filter_round``)
        over the *current view* of the relation: the masked
        incremental-min d(x, S_new) update and the cross-block top-k merge
        for the φ·log n pivot share the pass; the Round-3 filter is then a
        host mask update.

    The paper charges Round 3 only O(|R_l|·|S_new|/m) because R shrinks
    every iteration — so the loop tracks the live row set and, when the
    survivors fall under ``compact_threshold`` of the current view,
    re-points the fold at an ``IndexedSource`` of the survivors (their
    *original* row indices): later passes touch |R∪H| rows, not n.

    Every comparison is evaluated in f32 exactly as the device path's jit
    traces it, so the two paths return bitwise-identical samples for the
    same key (any blocking, compacted or not — the sampler is counter-
    based on original row ids, the d(x,S) update is per-row, and min/top-k
    value folds are blocking-invariant).
    """
    if type(executor).run_filter_round is Executor.run_filter_round:
        # Fail before the loop does any work (a bare Executor subclass
        # without the per-iteration hook cannot run the filter rounds).
        raise NotImplementedError(
            f"{type(executor).__name__} does not implement EIM's "
            "run_filter_round; use HostStreamExecutor (streamed), "
            "SimExecutor (vmapped machines) or MeshExecutor (sharded)")
    n = source.n
    _, threshold, s_cap, _, rank, num_s, num_h = _params(n, k, eps, phi)
    rows = (executor.rows_for(source) if hasattr(executor, "rows_for")
            else engine.resolve_block_rows(n, source.d))

    r_mask = np.ones(n, bool)
    s_mask = np.zeros(n, bool)
    d_s = np.full(n, np.float32(_BIG), np.float32)
    sampled = bool(n > threshold)
    try:
        iters, overflow = _stream_loop(
            source, executor, jnp.asarray(key), r_mask, s_mask, d_s,
            threshold, s_cap, rank, num_s, num_h, rows, max_iters,
            impl, chunk, compact_threshold)
    finally:
        # Release any per-source state the executor cached across the
        # filter rounds (e.g. SimExecutor's materialized blocking).
        executor.end_filter_rounds(source)
    return EIMSample(r_mask | s_mask, s_mask, np.int32(iters),
                     np.int32(overflow), sampled)


def _stream_loop(source, executor, key, r_mask, s_mask, d_s, threshold,
                 s_cap, rank, num_s, num_h, rows, max_iters, impl, chunk,
                 compact_threshold):
    """The iteration loop of ``_eim_sample_stream`` (mutates the host
    relations in place; returns ``(iterations, overflow)``).

    ``view_idx`` tracks the fold substrate: ``None`` means the identity
    view (every pass touches all n source rows, the pre-compaction
    behavior); otherwise it holds the sorted *original* row indices of the
    current ``IndexedSource`` view and ``d_view`` the matching slice of
    ``d_s``. Invariant: the live relation R (``r_mask``) is always a
    subset of the view — views are created from R and R only shrinks — so
    sampling, the pivot's H, and the Round-3 filter see exactly the same
    rows the full pass would.
    """
    n = source.n
    overflow = 0
    it = 0
    view = source          # current fold substrate (IndexedSource once compacted)
    view_idx = None        # None => identity view over all n rows
    d_view = d_s           # per-view slice of d_s (aliases d_s when identity)
    while (np.float32(int(r_mask.sum())) > np.float32(threshold)
           and it < max_iters):
        keys = jax.random.split(key, 3)
        key, k_s, k_h = keys[0], keys[1], keys[2]
        r_size = np.float32(int(r_mask.sum()))
        p_s = np.minimum(np.float32(num_s) / r_size, np.float32(1.0))
        p_h = np.minimum(np.float32(num_h) / r_size, np.float32(1.0))

        # --- Round 1: counter-based sampling, no data pass --------------
        # Draws are keyed by the *original* absolute row index (the view's
        # ``indices``), so the sampled sets are bitwise invariant to
        # whether/when compaction happened.
        if view_idx is None:
            new_s = _bernoulli_mask(k_s, n, p_s, rows) & r_mask
            h_view = _bernoulli_mask(k_h, n, p_h, rows) & r_mask
            s_idx = np.nonzero(new_s)[0]
        else:
            sub_r = r_mask[view_idx]
            new_s = _bernoulli_mask_at(k_s, view_idx, p_s, rows) & sub_r
            h_view = _bernoulli_mask_at(k_h, view_idx, p_h, rows) & sub_r
            s_idx = view_idx[new_s]
        # The device path's fixed S-buffer drops samples past s_cap (first-
        # index-first, a <1e-6 event at the default headroom); replicate
        # for parity and count the drops. Padding the gathered buffer up to
        # s_cap with the same far-away sentinel the device path uses keeps
        # the executor's block kernel one fixed shape across iterations
        # (padded rows can never win the distance min).
        overflow += max(0, len(s_idx) - s_cap)
        if len(s_idx):
            taken = source.take(s_idx[:s_cap])
            pad = s_cap - taken.shape[0]
            s_new = (taken if pad == 0 else np.concatenate(
                [taken, np.full((pad, taken.shape[1]), 1e18, np.float32)]))
        else:
            s_new = None
        # Termination fix (paper §4.1): sampled points always leave R.
        if view_idx is None:
            s_mask |= new_s
            r_mask &= ~new_s
        else:
            s_mask[s_idx] = True
            r_mask[s_idx] = False

        # --- Rounds 2-3: streamed d(x,S) update + pivot Select ----------
        # One fold over the *view* — |view| rows move, not n.
        d_view, pivot = executor.run_filter_round(view, s_new, d_view,
                                                  h_view, rank, impl=impl,
                                                  chunk=chunk)
        if view_idx is None:
            r_mask &= ~(d_s <= pivot)      # d_view aliases d_s here
        else:
            r_mask[view_idx[d_view <= pivot]] = False
        it += 1

        # --- compact the relation between iterations (paper §4's
        # shrinking |R|) --------------------------------------------------
        live = int(r_mask.sum())
        if np.float32(live) <= np.float32(threshold):
            break                          # loop is over; skip the re-view
        cur = n if view_idx is None else len(view_idx)
        # Multi-process, compaction is skipped: an IndexedSource re-view
        # would route per-shard block reads through the cross-process
        # ``take`` collective with *different* indices per process — a
        # protocol mismatch. The sample is bitwise invariant to the knob
        # (PR 4's contract), so skipping only costs the shrinking-|R|
        # speedup, never parity.
        if (live < compact_threshold * cur and live < cur
                and compat.process_count() == 1):
            if view is not source:
                # Release per-view executor caches (e.g. SimExecutor's
                # blocked copy) before the old view is dropped.
                executor.end_filter_rounds(view)
            if view_idx is not None:
                d_s[view_idx] = d_view     # scatter state back first
            view_idx = np.nonzero(r_mask)[0]
            view = IndexedSource(source, view_idx)
            d_view = d_s[view_idx]
    if view is not source:
        executor.end_filter_rounds(view)
    return it, overflow


def _bernoulli_mask(key, n: int, p: np.float32, rows: int) -> np.ndarray:
    """(n,) host bool mask of per-global-row Bernoulli(p) draws, generated
    in ``rows``-sized index blocks (the mask is O(n) bits on the host; the
    device working set is O(rows))."""
    parts = []
    for start in range(0, n, rows):
        parts.append(np.asarray(engine.bernoulli_rows_block(
            key, np.uint32(start & 0xFFFFFFFF),
            np.uint32((start >> 32) & 0xFFFFFFFF),
            min(rows, n - start), np.float32(p))))
    return (np.concatenate(parts) if parts
            else np.zeros((0,), bool))


def _bernoulli_mask_at(key, idx: np.ndarray, p: np.float32,
                       rows: int) -> np.ndarray:
    """Gather-form ``_bernoulli_mask``: per-row Bernoulli(p) draws at the
    given *original* absolute row indices (a compacted view's survivors),
    in ``rows``-sized blocks padded to one fixed shape — so one
    compilation of the jitted gather sampler serves every view size, and
    draw i is bitwise the full-range draw at row ``idx[i]``."""
    out = np.empty(idx.size, bool)
    for start in range(0, idx.size, rows):
        sub = idx[start:start + rows]
        nb = sub.size
        lo, hi = engine.split_index_words(sub)
        if nb < rows:
            lo = np.pad(lo, (0, rows - nb))
            hi = np.pad(hi, (0, rows - nb))
        blk = np.asarray(engine.bernoulli_rows_at_block(
            key, lo, hi, np.float32(p)))
        out[start:start + nb] = blk[:nb]
    return out
