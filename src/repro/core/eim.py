"""EIM — parameterized iterative-sampling k-center (paper §4, Algorithms 2–3).

Re-implementation of Ene/Im/Moseley's MapReduce sampling scheme with the
paper's two modifications:

  * **Termination fix** (paper §4.1): points sampled into S are *always*
    removed from R, and the removal test is ``d(x,S) <= d(v,S)`` (ties
    removed), so |R| strictly decreases and the loop cannot stall.
  * **φ parameter** (paper §4.2 / Algorithm 3): the pivot v is the
    ``φ·log n``-th farthest point of H from S (original scheme: φ = 8).
    φ > 5.15 keeps the 10-approximation w.s.p. (paper §6); smaller φ
    trades the guarantee for fewer/cheaper iterations.

TPU/JAX adaptation (DESIGN.md §2): MapReduce's shrinking relations R, S, H
become **masks over a fixed (n,d) array** — XLA needs static shapes, so
"remove from R" clears a mask bit and set sizes are mask sums. The
per-iteration work is O(n · s_new) distance updates, matching the paper's
Round-3 cost O(|R|·|S_l|/m); everything is data-parallel over n, so under
pjit the n axis shards across the mesh and each iteration's rounds map
onto collectives exactly as the MapReduce rounds map onto shuffles.

The loop is a ``lax.while_loop`` with the paper's condition
``|R| > (4/ε)·k·n^ε·log n`` (+ an iteration cap as a safety net; the paper
proves O(1/ε) iterations w.h.p. and observes ≤ 2 in practice).

Per-iteration sampled sets are materialized into *fixed-capacity* index
buffers (expected size 9k·n^ε·log n for S-samples, 4·n^ε·log n for H,
sized with 3σ Poisson headroom). Overflow beyond capacity is dropped and
counted (``stats.overflow``) — with the default headroom this is a
<1e-6-probability event, and dropping only *slows* convergence, never
breaks correctness of the returned sample.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.data.source import as_device_array
from repro.kernels import ops

from .gonzalez import covering_radius, gonzalez

_NEG = jnp.float32(-3.4e38)
_BIG = jnp.float32(3.4e38)


class EIMSample(NamedTuple):
    sample_mask: jnp.ndarray   # (n,) bool — C = S ∪ R_final
    s_mask: jnp.ndarray        # (n,) bool — sampled centers S
    iters: jnp.ndarray         # ()   int32 — while-loop iterations used
    overflow: jnp.ndarray      # ()   int32 — samples dropped by buffer caps
    sampled: jnp.ndarray       # ()   bool  — False => loop never ran (EIM≡GON)


class EIMResult(NamedTuple):
    centers: jnp.ndarray       # (k, d)
    radius2: jnp.ndarray       # ()
    sample: EIMSample


def _expected_caps(n: int, k: int, eps: float, slack: float = 3.0):
    """Fixed buffer capacities with Poisson 3σ-ish headroom."""
    ln_n = math.log(max(n, 2))
    es = 9.0 * k * (n ** eps) * ln_n
    eh = 4.0 * (n ** eps) * ln_n
    s_cap = int(min(n, math.ceil(es + slack * math.sqrt(es) + 16)))
    h_cap = int(min(n, math.ceil(eh + slack * math.sqrt(eh) + 16)))
    return s_cap, h_cap


def eim_sample(
    points,
    k: int,
    key: jax.Array,
    *,
    eps: float = 0.1,
    phi: float = 8.0,
    max_iters: int = 64,
    impl: str = "auto",
    chunk: int | None = None,
) -> EIMSample:
    """Algorithm 2 (EIM-MapReduce-Sample) with the φ-parameterized Select.

    ``points`` may be a ``PointSource``; it is materialized on device —
    EIM's shrinking relations are masks over a fixed (n,d) array, so the
    algorithm fundamentally needs random access (out-of-core callers
    should reach for ``mrg`` with a ``HostStreamExecutor`` instead).

    ``chunk`` streams the per-iteration (n, s_cap) distance update in
    row-blocks (kernels/engine.py memory model) — the sample distribution
    is unchanged: the PRNG stream is identical and, for inputs whose
    coordinates are far below the 1e18 invalid-slot sentinel, so is every
    distance the loop compares.
    """
    return _eim_sample_device(as_device_array(points), k, key, eps=eps,
                              phi=phi, max_iters=max_iters, impl=impl,
                              chunk=chunk)


@functools.partial(
    jax.jit, static_argnames=("k", "eps", "phi", "max_iters", "impl", "chunk")
)
def _eim_sample_device(
    points: jnp.ndarray,
    k: int,
    key: jax.Array,
    *,
    eps: float = 0.1,
    phi: float = 8.0,
    max_iters: int = 64,
    impl: str = "auto",
    chunk: int | None = None,
) -> EIMSample:
    n, d = points.shape
    points = points.astype(jnp.float32)
    ln_n = math.log(max(n, 2))
    threshold = (4.0 / eps) * k * (n ** eps) * ln_n
    s_cap, h_cap = _expected_caps(n, k, eps)
    # Select(): pivot rank φ·log n (>=1), clipped to the H buffer.
    rank = max(1, min(h_cap, int(round(phi * ln_n))))

    def cond(state):
        r_mask, s_mask, d_s, key, it, ovf = state
        return (jnp.sum(r_mask) > threshold) & (it < max_iters)

    def body(state):
        r_mask, s_mask, d_s, key, it, ovf = state
        key, k_s, k_h = jax.random.split(key, 3)
        r_size = jnp.sum(r_mask).astype(jnp.float32)

        # --- Round 1: independent sampling within R (Alg. 2, lines 3-4) ---
        p_s = jnp.minimum(9.0 * k * (n ** eps) * ln_n / r_size, 1.0)
        p_h = jnp.minimum(4.0 * (n ** eps) * ln_n / r_size, 1.0)
        new_s = jax.random.bernoulli(k_s, p_s, (n,)) & r_mask
        h_mask = jax.random.bernoulli(k_h, p_h, (n,)) & r_mask

        # Materialize new S members into a fixed buffer (gather indices).
        s_idx = jnp.nonzero(new_s, size=s_cap, fill_value=n)[0]
        s_valid = s_idx < n
        ovf = ovf + (jnp.sum(new_s) - jnp.sum(s_valid)).astype(jnp.int32)
        s_pts = points[jnp.minimum(s_idx, n - 1)]           # (s_cap, d)

        # Incremental d(x, S) update: distances to the *new* members only
        # (the paper's Round-3 O(|R|·|S|/m) term). Invalid buffer slots are
        # moved to a far-away coordinate so they never win the min; routing
        # through assign_nearest (a fused min-reduction) instead of
        # pairwise keeps the chunked peak at O(chunk·s_cap) — a chunked
        # pairwise would still stack the full (n, s_cap) block. The update
        # is gated on having any valid sample so a zero-sample iteration
        # leaves the uncovered-point sentinel (_BIG) exactly untouched.
        far_pts = jnp.where(s_valid[:, None], s_pts, 1e18)
        _, d_new = ops.assign_nearest(points, far_pts, impl=impl,
                                      chunk=chunk)            # (n,)
        d_s = jnp.where(jnp.any(s_valid), jnp.minimum(d_s, d_new), d_s)

        s_mask = s_mask | new_s
        # Termination fix (paper §4.1): sampled points always leave R.
        r_mask = r_mask & ~new_s

        # --- Round 2: Select(H, S) (Alg. 3) ----------------------------
        d_h = jnp.where(h_mask, d_s, _NEG)
        top = jax.lax.top_k(d_h, rank)[0]
        pivot = top[rank - 1]                                # d(v, S)^2
        # If H had fewer than `rank` valid points, pivot is _NEG: no
        # distance-based removals this iteration (sampling still shrinks R).
        pivot = jnp.where(pivot <= _NEG / 2, -1.0, pivot)

        # --- Round 3: filter R (Alg. 2, lines 7-8) ----------------------
        r_mask = r_mask & ~(d_s <= pivot)
        return r_mask, s_mask, d_s, key, it + 1, ovf

    r0 = jnp.ones((n,), bool)
    s0 = jnp.zeros((n,), bool)
    d0 = jnp.full((n,), _BIG)
    sampled = jnp.asarray(n > threshold)
    r_mask, s_mask, _, _, iters, ovf = jax.lax.while_loop(
        cond, body, (r0, s0, d0, key, jnp.int32(0), jnp.int32(0))
    )
    return EIMSample(r_mask | s_mask, s_mask, iters, ovf, sampled)


def eim(
    points,
    k: int,
    key: jax.Array,
    *,
    eps: float = 0.1,
    phi: float = 8.0,
    max_iters: int = 64,
    impl: str = "auto",
    chunk: int | None = None,
    compact: bool = True,
) -> EIMResult:
    """Full EIM: sample, then run GON on the sample (final MapReduce round).

    ``points`` may be a ``PointSource`` (materialized on device — see
    ``eim_sample``). With ``compact=True`` the sample is gathered into a
    dense buffer of static size (the paper's |C| <= (4/ε)k·n^ε·log n + |S|
    bound) before the final GON — this is the "send S ∪ R to one machine"
    round; the final GON then costs O(k·|C|) instead of O(k·n).
    """
    points = as_device_array(points)
    n, d = points.shape
    sample = eim_sample(points, k, key, eps=eps, phi=phi,
                        max_iters=max_iters, impl=impl, chunk=chunk)
    if compact:
        ln_n = math.log(max(n, 2))
        thr = (4.0 / eps) * k * (n ** eps) * ln_n
        s_cap, _ = _expected_caps(n, k, eps)
        c_cap = int(min(n, math.ceil(thr) + s_cap * (max_iters // 8 + 1)))
        idx = jnp.nonzero(sample.sample_mask, size=c_cap, fill_value=n)[0]
        valid = idx < n
        pts = jnp.asarray(points, jnp.float32)[jnp.minimum(idx, n - 1)]
        res = gonzalez(pts, k, mask=valid, impl=impl, chunk=chunk)
    else:
        res = gonzalez(jnp.asarray(points, jnp.float32), k,
                       mask=sample.sample_mask, impl=impl, chunk=chunk)
    r = covering_radius(points, res.centers, impl=impl, chunk=chunk)
    return EIMResult(res.centers, r * r, sample)
