"""(k,z)-center — k-center with z outliers over the weighted-fold substrate.

The MapReduce form follows Ceccarello–Pietracaprina–Pucci (arXiv
1802.09205): round 1 builds a *weighted coreset* — every machine-block is
reduced by GON to ``t = k + z`` centers and each center carries the total
weight of the rows it absorbed (``weighted_gon_block_fn``); the reducer
then solves the sequential outlier problem *on the coreset only*
(Charikar et al.'s greedy disk argument, weighted), so the outlier-aware
step is O(coreset²) host work — never O(n). The covering radius of the
result excludes the z farthest points via the streamed top-(z+1) fold
(``engine.fold_top_k_min_d2``), so no step of the pipeline materializes
the source.

Everything here is a *plugin* over the source × executor stack: the
rounds are ``Executor.run_blocks`` / ``combine_weighted`` / ``radius2``
driven by a weighted ``Objective`` descriptor — the same machinery (and
bits) as plain MRG, plus a weight operand.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.data.source import ArraySource, as_source, has_weights, is_source
from repro.kernels import ops

from .executor import (
    Executor,
    HostStreamExecutor,
    Objective,
    SimExecutor,
    weighted_gon_block_fn,
)


class KZResult(NamedTuple):
    centers: jnp.ndarray     # (k, d) the outlier-aware centers
    radius2: jnp.ndarray     # ()     squared radius excluding the z farthest
    coreset_size: int        # weighted-coreset rows the host solve saw
    rounds: int              # MapReduce rounds (2 = one coreset level)


# ---------------------------------------------------------------------------
# The sequential weighted solve (host, O(coreset²))
# ---------------------------------------------------------------------------

def _weighted_charikar(pts: np.ndarray, w: np.ndarray, k: int, z: float):
    """Charikar et al.'s greedy disk cover on a *weighted* instance.

    Binary-searches the candidate radii (the pairwise distances — OPT is
    one of them): at guess r, greedily pick the point whose r-ball covers
    the most uncovered weight, remove the 3r-ball, k times; feasible iff
    the uncovered weight is <= z. For any r >= OPT the greedy is feasible
    (the classical disk argument, weights included — each optimal ball is
    wiped by some chosen 3r-ball), so the search converges to a feasible
    guess <= the smallest candidate >= OPT and the chosen centers cover
    all but weight z within 3·OPT.

    Returns ``(sel (k,) indices into pts, r2)`` with ``r2`` the squared
    feasible guess. All float64 — the instance is coreset-sized.
    """
    c = pts.shape[0]
    if k >= c:
        return np.arange(c, dtype=np.int64), 0.0
    pts = np.asarray(pts, np.float64)
    w = np.asarray(w, np.float64)
    diff = pts[:, None, :] - pts[None, :, :]
    d2 = np.maximum((diff * diff).sum(-1), 0.0)       # (c, c)
    cand = np.unique(d2)

    def greedy(r2):
        sel = np.empty((k,), np.int64)
        uncovered = w.copy()
        for i in range(k):
            cover = (d2 <= r2) @ uncovered            # weight in each r-ball
            j = int(np.argmax(cover))
            sel[i] = j
            uncovered[d2[j] <= 9.0 * r2] = 0.0        # wipe the 3r-ball
        return sel, float(uncovered.sum())

    lo, hi = 0, cand.size - 1                         # hi: one ball covers all
    while lo < hi:
        mid = (lo + hi) // 2
        _, left = greedy(cand[mid])
        if left <= z + 1e-6:
            hi = mid
        else:
            lo = mid + 1
    sel, _ = greedy(cand[lo])
    return sel, float(cand[lo])


# ---------------------------------------------------------------------------
# The MapReduce algorithm
# ---------------------------------------------------------------------------

def kz_center(points, k: int, z: int, *, t: int | None = None,
              executor: Executor | None = None, m: int = 50,
              solve_capacity: int | None = None, impl: str = "auto",
              chunk: int | None = None) -> KZResult:
    """k-center with z outliers (Ceccarello et al. 1802.09205, streamed).

    ``points`` is anything ``as_source`` accepts — including a
    ``WeightedSource`` (its row weights seed the coreset weights; ``z``
    then bounds the excluded *weight*, counted in source rows). Source
    and executor defaulting mirror ``mrg``: raw arrays / ``ArraySource``
    run on ``SimExecutor(m)``; any host/disk/generator source streams on
    ``HostStreamExecutor()``.

    ``t`` (default ``k + z``) is the per-machine coreset size — the
    paper's τ; larger t tightens the coreset at more reducer work. If the
    round-1 union exceeds ``solve_capacity`` (default
    ``max(2048, 2·t)``), extra weighted Lemma-3 levels
    (``combine_weighted(..., final_gon=False)``) shrink it first — each
    level relaxes the approximation exactly as in plain MRG.

    Returns ``KZResult``: k centers, the squared covering radius
    *excluding the z farthest points* (a streamed top-(z+1) fold over the
    original source), the coreset size the host solve saw, and the round
    count.

    >>> import numpy as np
    >>> rng = np.random.default_rng(0)
    >>> x = rng.normal(size=(500, 2)).astype(np.float32)
    >>> x[:3] += 100.0                          # 3 far outliers
    >>> res = kz_center(x, 4, 3, m=5)
    >>> res.centers.shape
    (4, 2)
    >>> float(res.radius2) < 100.0              # outliers excluded
    True
    """
    if k < 1:
        raise ValueError(f"need k >= 1, got k={k}")
    if z < 0:
        raise ValueError(f"need z >= 0, got z={z}")
    t = int(k + z) if t is None else int(t)
    if t < k:
        raise ValueError(f"coreset size t={t} must be >= k={k}")
    streamed = is_source(points) and not isinstance(points, ArraySource)
    if streamed:
        source = as_source(points)
    else:
        source = points if isinstance(points, ArraySource) \
            else ArraySource(points)
    if executor is None:
        executor = (HostStreamExecutor() if streamed else SimExecutor(m=m))
    objective = Objective(name="kz_center", weighted=True, outliers=int(z))

    # Round 1: per-machine weighted GON — t centers per block, each
    # carrying the weight of the rows it absorbed (the paper's composable
    # weighted coreset).
    fn = weighted_gon_block_fn(t, impl, chunk)
    centers, valid, cw = executor.run_blocks(fn, source, objective=objective)

    # Optional intermediate levels: shrink the union to the host-solve
    # capacity, weights re-aggregated per level (Lemma 3, weighted).
    if solve_capacity is None:
        solve_capacity = max(2048, 2 * t)
    extra = 0
    if centers.shape[0] > solve_capacity:
        centers, cw, valid, extra = executor.combine_weighted(
            centers, valid, cw, t, solve_capacity, impl=impl, chunk=chunk,
            final_gon=False)

    # The sequential outlier-aware solve on the weighted coreset (host,
    # float64, O(coreset²) — never O(n)). Zero-weight rows absorbed no
    # points and carry no objective mass; drop them with the invalid ones.
    cn = np.asarray(centers, np.float64)
    wn = np.asarray(cw, np.float64)
    keep = np.asarray(valid, bool) & (wn > 0)
    cpts, cwts = cn[keep], wn[keep]
    if cpts.shape[0] == 0:
        raise ValueError("empty coreset — source has no positive-weight rows")
    sel, _ = _weighted_charikar(cpts, cwts, k, float(z))
    if sel.size < k:                        # coreset smaller than k: repeat
        sel = np.concatenate([sel, np.full(k - sel.size, sel[0], np.int64)])
    kcenters = jnp.asarray(cpts[sel].astype(np.float32))

    # The (k,z) objective value over the ORIGINAL source: streamed
    # top-(z+1) fold — slot z is the radius after excluding the z farthest.
    r2 = executor.radius2(source, kcenters, impl=impl, chunk=chunk,
                          objective=objective)
    return KZResult(kcenters, r2, int(cpts.shape[0]), 2 + extra)


def covering_radius_excluding(points, centers, z: int, *, impl: str = "auto",
                              chunk: int | None = None,
                              block_rows: int | None = None,
                              memory_budget: int | None = None):
    """Euclidean covering radius of ``centers`` excluding the z farthest
    points — the (k,z) objective any center set scores under.

    One streamed top-(z+1) fold over the source (``fold_top_k_min_d2``):
    device residency is one block (plus the prefetch ring) and the
    (z+1,)-slot running top-k; weighted sources restrict candidacy to
    their positive-weight support. ``z=0`` is the plain covering radius.
    """
    if z < 0:
        raise ValueError(f"need z >= 0, got z={z}")
    src = as_source(points)
    c = jnp.asarray(np.asarray(centers, np.float32))
    top = ops.fold_top_k_min_d2(src, c, int(z) + 1, impl=impl, chunk=chunk,
                                block_rows=block_rows,
                                memory_budget=memory_budget,
                                weighted=has_weights(src))
    return jnp.sqrt(jnp.maximum(top[int(z)], jnp.float32(0.0)))
