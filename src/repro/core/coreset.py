"""k-center coreset selection — the framework integration of the paper.

Training-data curation by diversity: embed examples, run (distributed) MRG
on the embedding cloud, keep the k selected examples plus optionally their
cluster sizes as importance weights. This is the production use-case that
makes parallel k-center a *framework feature* rather than a standalone
algorithm (DESIGN.md §3): the same mesh that trains the model clusters its
own embedding stream.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.kernels import ops

from .gonzalez import gonzalez
from .mrg import mrg_distributed, mrg_sim


class Coreset(NamedTuple):
    indices: jnp.ndarray    # (k,)  selected example indices
    centers: jnp.ndarray    # (k,d) embedding-space centers
    weights: jnp.ndarray    # (k,)  cluster sizes (importance weights)
    radius2: jnp.ndarray    # ()    squared covering radius


def select_coreset(
    embeddings: jnp.ndarray,
    k: int,
    *,
    mesh: Mesh | None = None,
    shard_axes: Sequence[str] = ("data",),
    impl: str = "auto",
    chunk: int | None = None,
) -> Coreset:
    """Pick k maximally-diverse examples from ``embeddings (n,d)``.

    With a mesh, runs the paper's MRG across ``shard_axes`` (2 rounds,
    4-approx); without, runs plain GON (2-approx) on one device.
    ``chunk`` streams every O(n·k) distance pass in row-blocks
    (kernels/engine.py) so the embedding cloud can exceed the size an
    un-chunked (n, k) block would allow.
    """
    emb = embeddings.astype(jnp.float32)
    if mesh is not None:
        centers, r2 = mrg_distributed(emb, k, mesh, shard_axes=shard_axes,
                                      impl=impl, chunk=chunk)
    else:
        res = gonzalez(emb, k, impl=impl, chunk=chunk)
        centers, r2 = res.centers, res.radius2
    # Map centers back to concrete example indices + cluster sizes. The
    # reverse pass (nearest example per center) is chunked over the n
    # axis too — assign_nearest(centers, emb) would rebuild a (k, n)
    # block on the ref path.
    assign_idx, _ = ops.assign_nearest(emb, centers, impl=impl, chunk=chunk)
    weights = jnp.zeros((k,), jnp.float32).at[assign_idx].add(1.0)
    cidx = ops.argmin_dist2_over_rows(emb, centers, impl=impl, chunk=chunk)
    return Coreset(cidx, centers, weights, r2)


def embed_batches(
    apply_fn: Callable[[jnp.ndarray], jnp.ndarray],
    token_batches: Sequence[jnp.ndarray],
) -> jnp.ndarray:
    """Mean-pooled final hidden states per example, stacked over batches.

    ``apply_fn(tokens (b,s)) -> hidden (b,s,d)``; returns ``(n,d)``.
    """
    outs = []
    for tb in token_batches:
        h = apply_fn(tb)
        outs.append(jnp.mean(h, axis=1))
    return jnp.concatenate(outs, axis=0)
