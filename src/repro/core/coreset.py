"""k-center coreset selection — the framework integration of the paper.

Training-data curation by diversity: embed examples, run (distributed) MRG
on the embedding cloud, keep the k selected examples plus optionally their
cluster sizes as importance weights. This is the production use-case that
makes parallel k-center a *framework feature* rather than a standalone
algorithm (DESIGN.md §3): the same mesh that trains the model clusters its
own embedding stream.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.data.source import ArraySource, has_weights, is_source
from repro.kernels import ops

from .executor import Executor
from .gonzalez import gonzalez
from .mrg import mrg, mrg_distributed


class Coreset(NamedTuple):
    indices: jnp.ndarray    # (k,)  selected example indices
    centers: jnp.ndarray    # (k,d) embedding-space centers
    weights: jnp.ndarray    # (k,)  cluster sizes (importance weights)
    radius2: jnp.ndarray    # ()    squared covering radius


def select_coreset(
    embeddings,
    k: int,
    *,
    mesh: Mesh | None = None,
    shard_axes: Sequence[str] = ("data",),
    executor: Executor | None = None,
    impl: str = "auto",
    chunk: int | None = None,
    block_rows: int | None = None,
    memory_budget: int | None = None,
) -> Coreset:
    """Pick k maximally-diverse examples from ``embeddings (n,d)``.

    With a mesh, runs the paper's MRG across ``shard_axes`` (2 rounds,
    4-approx); with an ``executor``, runs MRG on that substrate (e.g.
    ``HostStreamExecutor`` for out-of-core embedding clouds); without
    either, runs plain GON (2-approx) — streamed if ``embeddings`` is a
    host/disk/generator ``PointSource``, so the embedding cloud is bounded
    by host RAM, not HBM. ``chunk`` streams every O(n·k) distance pass in
    row-blocks (kernels/engine.py) within a block.

    Returns a ``Coreset`` ``(indices (k,) i32, centers (k, d),
    weights (k,) — cluster sizes, summing to n, radius2 ())``. Reverse
    passes (weights, center→example indices) inherit the executor's
    ``block_rows``/``memory_budget``, so the out-of-core contract holds
    end to end.

    >>> import numpy as np
    >>> emb = np.random.default_rng(0).normal(size=(100, 8)).astype(np.float32)
    >>> cs = select_coreset(emb, 10)
    >>> cs.indices.shape, cs.centers.shape
    ((10,), (10, 8))
    >>> int(cs.weights.sum())      # every example lands in one cluster
    100
    """
    if is_source(embeddings):
        src = embeddings
        streamed = not isinstance(src, ArraySource)
    else:
        # Raw arrays (numpy included) keep the legacy device path — only an
        # explicit PointSource opts into streaming.
        src = ArraySource(embeddings)
        streamed = False
    if block_rows is None and memory_budget is None and executor is not None:
        # Inherit the executor's residency budget so the reverse passes
        # honor the same out-of-core contract as the MRG rounds.
        block_rows = getattr(executor, "block_rows", None)
        memory_budget = getattr(executor, "memory_budget", None)
    if mesh is not None:
        # reprolint: disable=R002 -- the fused mesh path shards a device-resident copy; whole-array residency is its premise
        centers, r2 = mrg_distributed(src.materialize(), k, mesh,
                                      shard_axes=shard_axes,
                                      impl=impl, chunk=chunk)
    elif executor is not None:
        res = mrg(src, k, executor=executor, impl=impl, chunk=chunk)
        centers, r2 = res.centers, res.radius2
    else:
        res = gonzalez(src, k, impl=impl, chunk=chunk, block_rows=block_rows,
                       memory_budget=memory_budget)
        centers, r2 = res.centers, res.radius2
    # Map centers back to concrete example indices + cluster sizes. The
    # reverse pass (nearest example per center) is chunked over the n
    # axis too — assign_nearest(centers, emb) would rebuild a (k, n)
    # block on the ref path.
    if streamed:
        # Fold both reverse passes over the source — block-bounded device
        # residency; counts and indices match the in-memory pass exactly
        # (first-occurrence ties, order-exact integer adds). A weighted
        # source accumulates its row weights instead of counts, so the
        # coreset's importance weights stay weighted instances end to end.
        weights = jnp.zeros((k,), jnp.float32)
        if has_weights(src):
            for idx, _, w_blk in ops.assign_nearest_source(
                    src, centers, impl=impl, chunk=chunk,
                    block_rows=block_rows, memory_budget=memory_budget,
                    with_weights=True):
                weights = weights.at[idx].add(w_blk)
        else:
            for idx, _ in ops.assign_nearest_source(
                    src, centers, impl=impl, chunk=chunk,
                    block_rows=block_rows, memory_budget=memory_budget):
                weights = weights.at[idx].add(1.0)
        cidx = ops.argmin_dist2_over_source(src, centers, impl=impl,
                                            chunk=chunk,
                                            block_rows=block_rows,
                                            memory_budget=memory_budget)
    else:
        # reprolint: disable=R002 -- non-streamed branch: caller passed an in-memory array, residency is unchanged
        emb = src.materialize()
        assign_idx, _ = ops.assign_nearest(emb, centers, impl=impl,
                                           chunk=chunk)
        weights = jnp.zeros((k,), jnp.float32).at[assign_idx].add(1.0)
        cidx = ops.argmin_dist2_over_rows(emb, centers, impl=impl,
                                          chunk=chunk)
    return Coreset(cidx, centers, weights, r2)


def embed_batches(
    apply_fn: Callable[[jnp.ndarray], jnp.ndarray],
    token_batches: Sequence[jnp.ndarray],
) -> jnp.ndarray:
    """Mean-pooled final hidden states per example, stacked over batches.

    ``apply_fn(tokens (b,s)) -> hidden (b,s,d)``; returns ``(n,d)``.
    """
    outs = []
    for tb in token_batches:
        h = apply_fn(tb)
        outs.append(jnp.mean(h, axis=1))
    return jnp.concatenate(outs, axis=0)
