"""Clustering quality metrics + exact baselines for tests.

``brute_force_opt`` enumerates all k-subsets (tiny n only) to give the true
optimum that the approximation-factor property tests compare against
(GON <= 2·OPT, 2-round MRG <= 4·OPT).
"""
from __future__ import annotations

import itertools

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def covering_radius2(points, centers, *, impl: str = "auto"):
    """Max over points of squared distance to the nearest center."""
    _, d2 = ops.assign_nearest(points, centers, impl=impl)
    return jnp.max(d2)


def assignment(points, centers, *, impl: str = "auto"):
    """Per-point nearest center index."""
    idx, _ = ops.assign_nearest(points, centers, impl=impl)
    return idx


def brute_force_opt(points: np.ndarray, k: int) -> float:
    """Exact k-center optimum (center set ⊆ points) by enumeration.

    O(C(n,k) · n · k) — only for n <~ 20 in tests. Returns the Euclidean
    (not squared) optimal covering radius.
    """
    pts = np.asarray(points, np.float64)
    n = pts.shape[0]
    if k >= n:
        return 0.0
    d2 = np.maximum(
        ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1), 0.0
    )
    best = np.inf
    for combo in itertools.combinations(range(n), k):
        r = d2[:, combo].min(axis=1).max()
        if r < best:
            best = r
    return float(np.sqrt(best))


def brute_force_opt_z(points: np.ndarray, k: int, z: int) -> float:
    """Exact (k,z)-center optimum (centers ⊆ points) by enumeration.

    For every k-subset, the objective is the covering radius after
    dropping the z farthest points — the (n-z-1)-th order statistic of
    the per-point min distances. O(C(n,k) · n · k); tiny n only. Returns
    the Euclidean optimum the outlier approximation-ratio tests divide by.
    """
    pts = np.asarray(points, np.float64)
    n = pts.shape[0]
    if k >= n or z >= n:
        return 0.0
    d2 = np.maximum(
        ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1), 0.0
    )
    best = np.inf
    for combo in itertools.combinations(range(n), k):
        md = d2[:, combo].min(axis=1)
        r = np.partition(md, n - z - 1)[n - z - 1]
        if r < best:
            best = r
    return float(np.sqrt(best))
