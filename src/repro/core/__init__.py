"""The paper's contribution: parallel k-center clustering in JAX.

  gonzalez.py — GON, the sequential greedy 2-approximation (vectorized;
                also the out-of-core streamed form over a PointSource)
  executor.py — the paper's "machines": Sim (vmap) / Mesh (shard_map) /
                HostStream (out-of-core super-shards) executors
  mrg.py      — MRG, multi-round MapReduce Gonzalez — one algorithm over
                any executor (mrg_sim / mrg_distributed kept as wrappers)
  eim.py      — EIM, φ-parameterized iterative sampling (Ene et al. fixed;
                device masks or streamed out-of-core over any executor)
  metrics.py  — covering radius, assignment, brute-force OPT (tests)
  coreset.py  — k-center coreset selection (framework data-curation hook)
  outliers.py — (k,z)-center with outliers: weighted coreset + host solve
                (Ceccarello et al. 1802.09205 over the weighted folds)
"""
from .coreset import Coreset, embed_batches, select_coreset  # noqa: F401
from .eim import EIMResult, EIMSample, eim, eim_sample  # noqa: F401
from .executor import (  # noqa: F401
    Executor,
    HostStreamExecutor,
    MeshExecutor,
    Objective,
    SimExecutor,
)
from .gonzalez import GonzalezResult, covering_radius, gonzalez  # noqa: F401
from .metrics import (  # noqa: F401
    assignment,
    brute_force_opt,
    brute_force_opt_z,
    covering_radius2,
)
from .mrg import MRGResult, mrg, mrg_distributed, mrg_sim, plan_rounds  # noqa: F401
from .outliers import KZResult, covering_radius_excluding, kz_center  # noqa: F401
from .streaming import (  # noqa: F401
    StreamState,
    stream_init,
    stream_result,
    stream_update,
)
