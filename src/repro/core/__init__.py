"""The paper's contribution: parallel k-center clustering in JAX.

  gonzalez.py — GON, the sequential greedy 2-approximation (vectorized)
  mrg.py      — MRG, multi-round MapReduce Gonzalez (sim + shard_map forms)
  eim.py      — EIM, φ-parameterized iterative sampling (Ene et al. fixed)
  metrics.py  — covering radius, assignment, brute-force OPT (tests)
  coreset.py  — k-center coreset selection (framework data-curation hook)
"""
from .coreset import Coreset, embed_batches, select_coreset  # noqa: F401
from .eim import EIMResult, EIMSample, eim, eim_sample  # noqa: F401
from .gonzalez import GonzalezResult, covering_radius, gonzalez  # noqa: F401
from .metrics import assignment, brute_force_opt, covering_radius2  # noqa: F401
from .mrg import MRGResult, mrg_distributed, mrg_sim, plan_rounds  # noqa: F401
from .streaming import (  # noqa: F401
    StreamState,
    stream_init,
    stream_result,
    stream_update,
)
