"""Streaming k-center — beyond-paper extension (DESIGN.md §3).

The paper's MRG assumes the point set fits across the cluster's memory
(n/m ≤ c). For *unbounded streams* (the framework's embedding-curation
use-case: every training batch produces new embeddings), we add the
classic doubling algorithm (Charikar, Chekuri, Feder & Motwani 1997):
an 8-approximation that sees each point once and stores only k+1 points.

    state = stream_init(k, d)
    state = stream_update(state, batch)     # any number of times
    centers, radius_lb = stream_result(state)

Invariants (property-tested):
  * at most k centers are kept, pairwise separation > lower bound `r`;
  * every streamed point is within 8·OPT of some kept center (the
    algorithm guarantee; we test ≤ 8·GON-radius as an upper proxy).

The update is a host-side fold over jitted per-point kernels — streaming
is inherently sequential in the worst case, but each *batch* first drops
points already covered by the current centers (one vectorized
assign_nearest pass, the common case at steady state), so per-batch cost
is O(b·k) vectorized + rare sequential insertions.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.data.source import is_source
from repro.kernels import ops


class StreamState(NamedTuple):
    centers: np.ndarray    # (k, d) — rows beyond `count` are undefined
    count: int             # live centers
    r: float               # current lower-bound radius (doubling)
    k: int


def stream_init(k: int, d: int) -> StreamState:
    return StreamState(np.zeros((k + 1, d), np.float32), 0, 0.0, k)


def _min_d2(x: np.ndarray, centers: np.ndarray,
            chunk: int | None = None) -> np.ndarray:
    _, d2 = ops.assign_nearest(jnp.asarray(x), jnp.asarray(centers),
                               chunk=chunk)
    return np.asarray(d2)


def stream_update(state: StreamState, batch, *,
                  chunk: int | None = None,
                  block_rows: int | None = None,
                  memory_budget: int | None = None) -> StreamState:
    """Fold one batch of points (b,d) into the sketch.

    ``batch`` may also be any ``PointSource`` (host numpy, on-disk shards,
    or a generator program): its blocks are folded in order, so an entire
    out-of-core dataset can be sketched without ever materializing it —
    the natural pairing of the doubling algorithm's O(k) state with the
    source layer's O(block) residency. ``block_rows`` / ``memory_budget``
    set that blocking (kernels/engine.py residency model).

    ``chunk`` streams the per-batch coverage pass in row-blocks
    (kernels/engine.py) so arbitrarily large batches never materialize a
    (b, k) distance block."""
    if is_source(batch):
        rows = ops.resolve_block_rows(batch.n, batch.d,
                                      block_rows=block_rows,
                                      memory_budget=memory_budget)
        # The sketch's fold runs host-side, so prefer the source's numpy
        # blocks (no device round-trip); device-resident sources fall back
        # to pulling their blocks down.
        if hasattr(batch, "host_blocks"):
            blocks = batch.host_blocks(rows)
        else:
            blocks = (np.asarray(b) for b in batch.blocks(rows))
        for blk in blocks:
            state = stream_update(state, blk, chunk=chunk)
        return state
    centers, count, r, k = (np.array(state.centers), state.count,
                            state.r, state.k)
    batch = np.asarray(batch, np.float32)

    # bootstrap (only before the first doubling): the first k+1 points
    # define the initial r; afterwards insertion always requires > 4r.
    while r == 0.0 and count <= k and batch.size:
        centers[count] = batch[0]
        batch = batch[1:]
        count += 1
        if count == k + 1:
            # the (k+1, k+1) block is tiny; route through the façade so
            # impl resolution stays in one place (kernels/engine.py)
            d2 = np.array(ops.pairwise_dist2(
                jnp.asarray(centers), jnp.asarray(centers)))
            np.fill_diagonal(d2, np.inf)
            r = float(np.sqrt(d2.min())) / 2.0
            centers, count = _merge(centers, count, r, k)
    if not batch.size:
        return StreamState(centers, count, r, k)

    while batch.size:
        # vectorized drop of covered points (≤ 4r of a center: the
        # doubling invariant allows absorbing them)
        d2 = _min_d2(batch, centers[:count], chunk)
        far = batch[np.sqrt(d2) > 4.0 * r]
        if far.size == 0:
            break
        if count < k + 1:
            centers[count] = far[0]
            count += 1
            batch = far[1:]
            if count == k + 1:
                # classic doubling: never rest with more than k centers
                r *= 2.0
                centers, count = _merge(centers, count, r, k)
        else:
            r *= 2.0
            centers, count = _merge(centers, count, r, k)
            batch = far
    return StreamState(centers, count, r, k)


def _merge(centers: np.ndarray, count: int, r: float, k: int):
    """Greedy re-cluster of the kept centers at scale 4r: keep a maximal
    subset with pairwise distance > 4r."""
    kept = []
    for i in range(count):
        c = centers[i]
        if all(np.sum((c - centers[j]) ** 2) > (4.0 * r) ** 2
               for j in kept):
            kept.append(i)
    new = np.zeros_like(centers)
    new[: len(kept)] = centers[kept]
    return new, len(kept)


def stream_result(state: StreamState):
    """-> (centers (count,d), radius lower bound r)."""
    return state.centers[: state.count], state.r
