"""Streaming k-center — beyond-paper extension (DESIGN.md §3).

The paper's MRG assumes the point set fits across the cluster's memory
(n/m ≤ c). For *unbounded streams* (the framework's embedding-curation
use-case: every training batch produces new embeddings), we add the
classic doubling algorithm (Charikar, Chekuri, Feder & Motwani 1997):
an 8-approximation that sees each point once and stores only k+1 points.

    state = stream_init(k, d)
    state = stream_update(state, batch)     # any number of times
    centers, radius_lb = stream_result(state)

Invariants (property-tested):
  * at most k centers are kept, pairwise separation > lower bound `r`;
  * every streamed point is within 8·OPT of some kept center (the
    algorithm guarantee; we test ≤ 8·GON-radius as an upper proxy).

The update is a host-side fold over jitted per-point kernels — streaming
is inherently sequential in the worst case, but each *batch* first drops
points already covered by the current centers (one vectorized
assign_nearest pass, the common case at steady state), so per-batch cost
is O(b·k) vectorized + rare sequential insertions.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.data.source import is_source
from repro.kernels import ops


class StreamState(NamedTuple):
    centers: np.ndarray    # (k, d) — rows beyond `count` are undefined
    count: int             # live centers
    r: float               # current lower-bound radius (doubling)
    k: int


def stream_init(k: int, d: int) -> StreamState:
    return StreamState(np.zeros((k + 1, d), np.float32), 0, 0.0, k)


def _min_d2(x: np.ndarray, centers: np.ndarray,
            chunk: int | None = None) -> np.ndarray:
    _, d2 = ops.assign_nearest(jnp.asarray(x), jnp.asarray(centers),
                               chunk=chunk)
    return np.asarray(d2)


def stream_update(state: StreamState, batch, *,
                  chunk: int | None = None,
                  block_rows: int | None = None,
                  memory_budget: int | None = None,
                  tail: str = "host") -> StreamState:
    """Fold one batch of points (b,d) into the sketch.

    ``batch`` may also be any ``PointSource`` (host numpy, on-disk shards,
    or a generator program): its blocks are folded in order, so an entire
    out-of-core dataset can be sketched without ever materializing it —
    the natural pairing of the doubling algorithm's O(k) state with the
    source layer's O(block) residency. ``block_rows`` / ``memory_budget``
    set that blocking (kernels/engine.py residency model).

    ``chunk`` streams the per-batch coverage pass in row-blocks
    (kernels/engine.py) so arbitrarily large batches never materialize a
    (b, k) distance block.

    ``tail`` picks the sequential-insertion tail: ``"host"`` (default)
    checks insertion candidates against only the centers added since the
    batch's vectorized coverage pass — O(b·new) host flops, one device
    pass per doubling instead of one per insertion; ``"device"`` is the
    legacy per-insertion re-pass (one ``assign_nearest`` round-trip per
    inserted center), kept as the before/after micro-bench baseline
    (``benchmarks/serve_bench.py``, insert-heavy regime)."""
    if tail not in ("host", "device"):
        raise ValueError(f"tail must be 'host' or 'device', got {tail!r}")
    if is_source(batch):
        rows = ops.resolve_block_rows(batch.n, batch.d,
                                      block_rows=block_rows,
                                      memory_budget=memory_budget)
        # The sketch's fold runs host-side, so prefer the source's numpy
        # blocks (no device round-trip); device-resident sources fall back
        # to pulling their blocks down.
        if hasattr(batch, "host_blocks"):
            blocks = batch.host_blocks(rows)
        else:
            blocks = (np.asarray(b) for b in batch.blocks(rows))
        for blk in blocks:
            state = stream_update(state, blk, chunk=chunk, tail=tail)
        return state
    centers, count, r, k = (np.array(state.centers), state.count,
                            state.r, state.k)
    batch = np.asarray(batch, np.float32)

    # bootstrap (only before the first doubling): the first k+1 points
    # define the initial r; afterwards insertion always requires > 4r.
    while r == 0.0 and count <= k and batch.size:
        centers[count] = batch[0]
        batch = batch[1:]
        count += 1
        if count == k + 1:
            # the (k+1, k+1) block is tiny; route through the façade so
            # impl resolution stays in one place (kernels/engine.py)
            d2 = np.array(ops.pairwise_dist2(
                jnp.asarray(centers), jnp.asarray(centers)))
            np.fill_diagonal(d2, np.inf)
            r = float(np.sqrt(d2.min())) / 2.0
            centers, count = _merge(centers, count, r, k)
    if not batch.size:
        return StreamState(centers, count, r, k)

    while batch.size:
        # vectorized drop of covered points (≤ 4r of a center: the
        # doubling invariant allows absorbing them) — ONE device pass
        d2 = _min_d2(batch, centers[:count], chunk)
        dist = np.sqrt(d2)
        keep = dist > 4.0 * r
        batch, dist = batch[keep], dist[keep]
        if batch.size == 0:
            break
        batch, centers, count, r = _insert_tail(
            batch, dist, centers, count, r, k,
            one_per_pass=(tail == "device"))
    return StreamState(centers, count, r, k)


def _insert_tail(batch: np.ndarray, dist: np.ndarray, centers: np.ndarray,
                 count: int, r: float, k: int, *, one_per_pass: bool):
    """Sequential-insertion tail of one ``stream_update`` coverage pass.

    Every row of ``batch`` already failed the ≤4r coverage test against the
    pass-time center set; ``dist`` caches those pass-time min-distances.
    Insertion candidates are re-checked host-side against only the centers
    *added since the pass* — O(b·new) flops, no per-point host↔device
    round-trip. A doubling+merge shrinks the center set to a subset of the
    pass-time centers, so the cached distances survive only as lower
    bounds; the tail hands the unconsumed rows back for a fresh vectorized
    pass instead of consuming stale bounds (that keeps ``_merge``'s
    coverage rebuild on the vectorized device path). A center inserted
    this tail is at true distance > 4r from every live center (cached
    distance ≤ true distance), so the doubling separation invariant holds
    exactly as in the legacy tail.

    ``one_per_pass=True`` reproduces the legacy device tail bit-for-bit:
    return after the first insertion so every candidate is re-screened by
    a fresh ``assign_nearest`` pass.
    """
    added: list = []                    # centers inserted since the pass
    for i in range(batch.shape[0]):
        x = batch[i]
        cd = float(dist[i])
        for c in added:
            diff = x - c
            cd = min(cd, float(np.sqrt(np.dot(diff, diff))))
        if cd <= 4.0 * r:
            continue                    # covered by a center added mid-tail
        if count < k + 1:
            centers[count] = x
            count += 1
            if count == k + 1:
                # classic doubling: never rest with more than k centers
                r *= 2.0
                centers, count = _merge(centers, count, r, k)
                return batch[i + 1:], centers, count, r
            added.append(x.copy())
            if one_per_pass:
                return batch[i + 1:], centers, count, r
        else:
            r *= 2.0
            centers, count = _merge(centers, count, r, k)
            return batch[i:], centers, count, r
    return batch[:0], centers, count, r


def _merge(centers: np.ndarray, count: int, r: float, k: int):
    """Greedy re-cluster of the kept centers at scale 4r: keep a maximal
    subset with pairwise distance > 4r. The rebuild is vectorized — one
    (count, count) distance block (count ≤ k+1) plus a masked greedy scan,
    no per-pair python distance loop."""
    live = centers[:count]
    diff = live[:, None, :] - live[None, :, :]
    d2 = np.einsum("ijk,ijk->ij", diff, diff)
    thr = (4.0 * r) ** 2
    ok = np.ones(count, bool)
    kept = []
    for i in range(count):
        if ok[i]:
            kept.append(i)
            ok &= d2[i] > thr
    new = np.zeros_like(centers)
    new[: len(kept)] = live[kept]
    return new, len(kept)


def stream_result(state: StreamState):
    """-> (centers (count,d), radius lower bound r)."""
    return state.centers[: state.count], state.r
