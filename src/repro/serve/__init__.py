"""Serving layer — two engines: the seed's LM continuous-batching
``Engine`` (token decode over fixed slots, serve/engine.py) and the
k-center query service ``KCenterService`` (batched nearest-center
assignment over a live streamed sketch, serve/kcenter.py)."""
from .engine import Engine, Request
from .kcenter import AssignResult, AssignTicket, KCenterService
from .sampler import sample

__all__ = [
    "Engine",
    "Request",
    "sample",
    "KCenterService",
    "AssignResult",
    "AssignTicket",
]
