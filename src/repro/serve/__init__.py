from .engine import Engine, Request  # noqa: F401
from .sampler import sample  # noqa: F401
