"""Continuous-batching serving engine.

vLLM-style slot scheduler adapted to JAX's static shapes: the engine owns
a fixed B×S_max KV cache ("slots"); requests are admitted into free slots,
every step decodes *all* active slots in one jitted `decode_step`, finished
requests (EOS or max_tokens) free their slot immediately — no
head-of-line blocking on the longest sequence in the batch.

JAX adaptation of the usual CUDA implementation (DESIGN.md hardware-
adaptation policy): slot state (positions, alive-mask, per-slot RNG) lives
in regular arrays; admission re-runs `prefill` for the incoming request
into a single slot via dynamic_update_slice of the shared cache — the
shapes never change, so there is exactly one compiled decode executable.

Scope: single-host driver loop (host Python schedules; device math is
jitted). On a pod this loop runs on host 0 with the same jitted steps
pjit-sharded — the cache layout is the decode_* dry-run layout.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig

from .sampler import sample


@dataclass
class Request:
    uid: int
    tokens: np.ndarray                  # prompt (p,)
    max_new: int = 32
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos_id: int = -1                    # -1: never stops on token
    # filled by the engine
    out: List[int] = field(default_factory=list)
    slot: int = -1
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine:
    """Fixed-slot continuous-batching engine over one model."""

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 8,
                 s_max: int = 512):
        self.params = params
        self.cfg = cfg
        self.B = slots
        self.S = s_max
        self.cache = init_cache(cfg, slots, s_max)
        self.alive = np.zeros(slots, bool)
        self.reqs: Dict[int, Request] = {}
        self.slot_req = [None] * slots
        self.pending: List[Request] = []
        self.done: List[Request] = []
        self.key = jax.random.PRNGKey(0)

        self._decode = jax.jit(
            lambda p, c, t: decode_step(p, c, t, cfg))
        # one prefill executable per prompt length bucket
        self._prefills: Dict[int, Callable] = {}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.time()
        self.pending.append(req)
        self.reqs[req.uid] = req

    def _prefill_fn(self, plen: int):
        if plen not in self._prefills:
            cfg = self.cfg

            def f(params, tokens):
                return prefill(params, {"tokens": tokens}, cfg, self.S)

            self._prefills[plen] = jax.jit(f)
        return self._prefills[plen]

    def _bucket(self, plen: int) -> int:
        b = 8
        while b < plen:
            b *= 2
        return min(b, self.S - 1)

    def _admit(self):
        """Move pending requests into free slots (prefill + cache splice)."""
        free = [i for i in range(self.B) if not self.alive[i]]
        while free and self.pending:
            slot = free.pop(0)
            req = self.pending.pop(0)
            plen = self._bucket(len(req.tokens))
            toks = np.zeros((1, plen), np.int32)
            toks[0, -len(req.tokens):] = req.tokens  # left-pad
            logits, rcache = self._prefill_fn(plen)(
                self.params, jnp.asarray(toks))
            # splice request cache into the engine cache at `slot`
            self.cache = _splice(self.cache, rcache, slot, self.cfg)
            self.cache["pos"] = self.cache["pos"].at[slot].set(plen)
            self.key, sub = jax.random.split(self.key)
            tok = int(sample(logits[:, -1], sub,
                             temperature=req.temperature,
                             top_k=req.top_k, top_p=req.top_p)[0])
            req.out.append(tok)
            req.t_first = time.time()
            req.slot = slot
            self.alive[slot] = True
            self.slot_req[slot] = req
            self._next_tok = getattr(self, "_next_tok",
                                     np.zeros(self.B, np.int32))
            self._next_tok[slot] = tok

    def _retire(self, slot: int):
        req = self.slot_req[slot]
        req.t_done = time.time()
        self.alive[slot] = False
        self.slot_req[slot] = None
        self.done.append(req)

    def step(self):
        """One engine step: admit, decode all live slots, sample, retire."""
        self._admit()
        if not self.alive.any():
            return False
        toks = jnp.asarray(
            getattr(self, "_next_tok", np.zeros(self.B, np.int32))
        )[:, None]
        logits, self.cache = self._decode(self.params, self.cache, toks)
        self.key, sub = jax.random.split(self.key)
        # batched sampling: per-slot params vary → sample greedily in one
        # shot, resample stochastic slots individually (rare path)
        nxt = np.array(sample(logits[:, -1], sub))  # writable host copy
        for slot in range(self.B):
            if not self.alive[slot]:
                continue
            req = self.slot_req[slot]
            if req.temperature > 0:
                self.key, s2 = jax.random.split(self.key)
                nxt[slot] = int(sample(
                    logits[slot : slot + 1, -1], s2,
                    temperature=req.temperature, top_k=req.top_k,
                    top_p=req.top_p)[0])
            tok = int(nxt[slot])
            req.out.append(tok)
            self._next_tok[slot] = tok
            if len(req.out) >= req.max_new or tok == req.eos_id:
                self._retire(slot)
            elif int(self.cache["pos"][slot]) >= self.S - 1:
                self._retire(slot)  # out of cache
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.pending or self.alive.any()) and steps < max_steps:
            self.step()
            steps += 1
        return self.done


def _splice(cache, rcache, slot: int, cfg: ModelConfig):
    """Copy request-cache (B=1) buffers into engine-cache slot ``slot``."""
    out = dict(cache)
    for k, v in cache.items():
        if k == "pos":
            continue
        r = rcache[k]
        # layer-stacked buffers: axis 1 is batch
        out[k] = jax.lax.dynamic_update_slice_in_dim(
            v, r.astype(v.dtype), slot, axis=1)
    return out
