"""High-QPS online k-center serving: batched nearest-center queries over a
live streamed sketch.

This is the ROADMAP's "online k-center serving engine": the last mile
between the streaming doubling sketch (``core/streaming.py``, Charikar–
Chekuri–Feder–Motwani) and a production query path. Ceccarello–
Pietracaprina–Pucci (arXiv 1802.09205) settle the *accuracy* side —
streamed k-center matches offline quality in one pass — so the engineering
problem left is throughput: answer ``assign(queries)`` at high QPS while
the center set evolves under continuous ingest.

Three mechanisms, mirroring the recompile-avoidance discipline of the
fused streamed kernels (PR 4/7):

  * **ingest / query separation** — ``submit_points`` enqueues point
    batches (or whole ``PointSource``s) for a dedicated ingest thread that
    folds them into the sketch via ``stream_update``; queries never wait
    on ingest compute, only on the snapshot lock (a few loads).
  * **epoch-versioned device-resident center cache** — the sketch's live
    centers publish under an epoch counter that bumps *only when the
    center set actually changes*; at a stable radius every covered point
    is absorbed without touching the centers, so the steady-state common
    case is zero invalidations. The query path keeps the centers
    device-resident in a fixed power-of-two bucket with a validity-mask
    operand; a stale epoch re-uploads the *same shapes* (no new program),
    and only crossing a power-of-two center count grows the bucket.
  * **fixed-shape micro-batching** — an admission queue coalesces
    concurrent ``assign`` calls into one micro-batch per device dispatch
    (continuous batching: while a batch is in flight, new arrivals pile up
    and ship together on the next dispatch). Each micro-batch is padded to
    a power-of-two row bucket, so every dispatch hits one of
    O(log max_batch) operand signatures — zero compilations after warmup,
    ragged arrival sizes included.

The device program is ``ops.assign_bucketed`` (kernels/engine.py): eager
by design so served answers are **bitwise** equal to the offline
``ops.assign_nearest`` on the same snapshot centers (jit fuses the matmul
differently on CPU — see the entry point's docstring), with ``impl=``
threaded through to the fused Pallas assignment tile on backends where it
lowers natively.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.streaming import stream_init, stream_update
from repro.data.source import is_source
from repro.kernels import ops

_SHUTDOWN = object()


def _pow2_at_least(n: int, floor: int) -> int:
    """Smallest power of two >= max(n, floor)."""
    b = max(int(floor), 1)
    while b < n:
        b *= 2
    return b


class AssignResult(NamedTuple):
    """One served assignment: nearest-center index + squared distance per
    query row, tagged with the center-set epoch that answered it."""
    idx: np.ndarray     # (b,) int32
    d2: np.ndarray      # (b,) float32
    epoch: int


class AssignTicket:
    """Handle for an in-flight ``assign_async`` request; ``result()``
    blocks until the dispatch thread answers (or raises its error).
    ``t_submit``/``t_done`` are ``time.monotonic`` stamps for load-gen
    latency accounting (the ``Engine.Request`` idiom)."""

    __slots__ = ("q", "t_submit", "t_done", "_event", "_idx", "_d2",
                 "_epoch", "_err")

    def __init__(self, q: np.ndarray):
        self.q = q
        self.t_submit = time.monotonic()
        self.t_done = 0.0
        self._event = threading.Event()
        self._err: Optional[BaseException] = None

    def _resolve(self, idx, d2, epoch) -> None:
        self._idx, self._d2, self._epoch = idx, d2, epoch
        self.t_done = time.monotonic()
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._err = err
        self.t_done = time.monotonic()
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> AssignResult:
        if not self._event.wait(timeout):
            raise TimeoutError("assign timed out")
        if self._err is not None:
            raise self._err
        return AssignResult(self._idx, self._d2, self._epoch)


class KCenterService:
    """Online k-center service: live ingest + batched assignment queries.

    ::

        svc = KCenterService(k=16, d=8)
        svc.submit_points(points)          # async: any (b, d) array or
        svc.drain()                        #        any PointSource
        res = svc.assign(queries)          # (idx, d2, epoch) — blocking
        epoch, centers, r = svc.snapshot() # the live sketch at `epoch`
        svc.close()

    ``assign`` is thread-safe and designed to be called from many client
    threads at once — concurrent calls coalesce into micro-batches.
    Contracts (tests/test_serve_kcenter.py):

      * every result is bitwise ``ops.assign_nearest(queries, centers)``
        for the snapshot centers of ``result.epoch``;
      * a dispatch's operand signature is a function of the (query-bucket,
        center-bucket) pair only — warmup covers them once, after which
        ragged query sizes and epoch bumps add zero signatures;
      * ingest that leaves the center set unchanged (covered points — the
        steady state) bumps no epoch and refreshes no cache.

    Knobs: ``batching=False`` dispatches every request alone (the bench's
    single-query baseline); ``max_batch`` caps coalesced rows per
    dispatch; ``batch_wait_s`` optionally lingers for stragglers (default
    0 — purely opportunistic coalescing); ``impl``/``chunk`` thread
    through to the query kernels; ``snapshot_history=True`` retains every
    epoch's centers (tests; O(epochs · k · d) host bytes).
    """

    def __init__(self, k: int, d: int, *, impl: str = "auto",
                 chunk: Optional[int] = None, max_batch: int = 256,
                 min_bucket: int = 8, center_bucket_min: int = 8,
                 batching: bool = True, batch_wait_s: float = 0.0,
                 ingest_tail: str = "host",
                 ingest_block_rows: Optional[int] = None,
                 ingest_memory_budget: Optional[int] = None,
                 snapshot_history: bool = False):
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self._k, self._d = int(k), int(d)
        self._impl, self._chunk = impl, chunk
        self._max_batch = int(max_batch)
        self._min_bucket = _pow2_at_least(min_bucket, 1)
        self._center_bucket_min = _pow2_at_least(center_bucket_min, 1)
        self._batching = bool(batching)
        self._batch_wait_s = float(batch_wait_s)
        self._ingest_tail = ingest_tail
        self._ingest_block_rows = ingest_block_rows
        self._ingest_memory_budget = ingest_memory_budget

        # -- sketch + published snapshot (epoch-versioned) ---------------
        self._state = stream_init(k, d)         # ingest-thread private
        self._mu = threading.Lock()
        self._epoch = 0                         # 0 = empty center set
        self._centers = np.zeros((0, d), np.float32)
        self._r = 0.0
        self._history: Optional[Dict[int, np.ndarray]] = (
            {} if snapshot_history else None)
        self._stats = {"queries": 0, "batches": 0, "batched_rows": 0,
                       "epochs": 0, "cache_refreshes": 0,
                       "bucket_growths": 0}

        # -- device-resident center cache (dispatch-thread private) ------
        self._cache_epoch = -1
        self._cache_mcap = 0
        self._cache_buf = None                  # (m_cap, d) device f32
        self._cache_mask = None                 # (m_cap,) device f32 0/1

        # -- ingest queue + admission queue ------------------------------
        self._ingest_q: queue.Queue = queue.Queue()
        self._req_q: queue.Queue = queue.Queue()
        self._ingest_cv = threading.Condition()
        self._ingest_pending = 0
        self._ingest_err: Optional[BaseException] = None
        self._closed = False

        self._ingest_thread = threading.Thread(
            target=self._ingest_loop, name="kcenter-ingest", daemon=True)
        self._dispatch_thread = threading.Thread(
            target=self._dispatch_loop, name="kcenter-dispatch", daemon=True)
        self._ingest_thread.start()
        self._dispatch_thread.start()

    # -- lifecycle --------------------------------------------------------
    def __enter__(self) -> "KCenterService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Stop both threads; outstanding requests fail with RuntimeError."""
        if self._closed:
            return
        self._closed = True
        self._ingest_q.put(_SHUTDOWN)
        self._req_q.put(_SHUTDOWN)
        self._ingest_thread.join()
        self._dispatch_thread.join()
        while True:                 # fail anything admitted after shutdown
            try:
                item = self._req_q.get_nowait()
            except queue.Empty:
                break
            if item is not _SHUTDOWN:
                item._fail(RuntimeError("KCenterService closed"))

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("KCenterService is closed")

    # -- ingest side ------------------------------------------------------
    def submit_points(self, points) -> None:
        """Asynchronously fold ``points`` — a (b, d) array or any
        ``PointSource`` — into the sketch. Returns immediately; ``drain``
        waits for completion (and surfaces ingest errors)."""
        self._check_open()
        self._raise_ingest_err()
        if not is_source(points):
            points = np.asarray(points, np.float32)
            if points.ndim != 2 or points.shape[1] != self._d:
                raise ValueError(
                    f"expected (b, {self._d}) points, got {points.shape}")
        with self._ingest_cv:
            self._ingest_pending += 1
        self._ingest_q.put(points)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted batch has been folded in."""
        with self._ingest_cv:
            if not self._ingest_cv.wait_for(
                    lambda: self._ingest_pending == 0, timeout):
                raise TimeoutError("ingest queue did not drain")
        self._raise_ingest_err()

    def _raise_ingest_err(self) -> None:
        with self._mu:
            err = self._ingest_err
        if err is not None:
            raise RuntimeError("ingest thread failed") from err

    def _ingest_loop(self) -> None:
        while True:
            item = self._ingest_q.get()
            if item is _SHUTDOWN:
                return
            try:
                old = self._state
                new = stream_update(
                    old, item, chunk=self._chunk,
                    block_rows=self._ingest_block_rows,
                    memory_budget=self._ingest_memory_budget,
                    tail=self._ingest_tail)
                self._state = new
                # Epoch bumps ONLY on a real center-set change — covered
                # points (the steady state) publish nothing.
                changed = (new.count != old.count or new.r != old.r
                           or not np.array_equal(new.centers[:new.count],
                                                 old.centers[:old.count]))
                if changed:
                    snap = np.array(new.centers[:new.count], np.float32)
                    with self._mu:
                        self._epoch += 1
                        self._centers = snap
                        self._r = new.r
                        self._stats["epochs"] += 1
                        if self._history is not None:
                            self._history[self._epoch] = snap
            except BaseException as e:  # noqa: BLE001 — surfaced via drain
                with self._mu:
                    self._ingest_err = e
            finally:
                with self._ingest_cv:
                    self._ingest_pending -= 1
                    self._ingest_cv.notify_all()

    # -- snapshots --------------------------------------------------------
    def snapshot(self) -> Tuple[int, np.ndarray, float]:
        """-> (epoch, centers (count, d), radius lower bound r). The
        centers array is the published copy — treat it as read-only."""
        with self._mu:
            return self._epoch, self._centers, self._r

    def snapshot_at(self, epoch: int) -> np.ndarray:
        """Centers of a historical epoch (requires snapshot_history)."""
        if self._history is None:
            raise RuntimeError(
                "snapshot_at needs KCenterService(snapshot_history=True)")
        with self._mu:
            return self._history[epoch]

    @property
    def stats(self) -> Dict[str, int]:
        with self._mu:
            return dict(self._stats)

    # -- query side -------------------------------------------------------
    def assign_async(self, queries) -> AssignTicket:
        """Submit a query batch (b, d); returns an ``AssignTicket`` whose
        ``result()`` blocks until the answer is dispatched."""
        self._check_open()
        q = np.asarray(queries, np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2 or q.shape[1] != self._d:
            raise ValueError(
                f"expected (b, {self._d}) queries, got {np.shape(queries)}")
        if q.shape[0] == 0:
            raise ValueError("empty query batch")
        ticket = AssignTicket(q)
        self._req_q.put(ticket)
        return ticket

    def assign(self, queries, timeout: Optional[float] = None) -> AssignResult:
        """Blocking ``assign_async(...).result()`` — the client call."""
        return self.assign_async(queries).result(timeout)

    # -- dispatch thread --------------------------------------------------
    def _dispatch_loop(self) -> None:
        while True:
            first = self._req_q.get()
            if first is _SHUTDOWN:
                return
            batch: List[AssignTicket] = [first]
            rows = first.q.shape[0]
            stop = False
            if self._batching:
                # Opportunistic coalescing: drain whatever piled up while
                # the previous dispatch was in flight (continuous
                # batching); optionally linger batch_wait_s for more.
                deadline = None
                if self._batch_wait_s > 0:
                    deadline = time.monotonic() + self._batch_wait_s
                while rows < self._max_batch:
                    try:
                        if deadline is None:
                            nxt = self._req_q.get_nowait()
                        else:
                            left = deadline - time.monotonic()
                            if left <= 0:
                                break
                            nxt = self._req_q.get(timeout=left)
                    except queue.Empty:
                        break
                    if nxt is _SHUTDOWN:
                        stop = True
                        break
                    batch.append(nxt)
                    rows += nxt.q.shape[0]
            self._run_batch(batch)
            if stop:
                return

    def _run_batch(self, batch: List[AssignTicket]) -> None:
        try:
            if len(batch) == 1:
                qcat = batch[0].q
            else:
                qcat = np.concatenate([t.q for t in batch], axis=0)
            idx, d2, epoch = self._dispatch(qcat)
            off = 0
            for t in batch:
                b = t.q.shape[0]
                t._resolve(idx[off:off + b], d2[off:off + b], epoch)
                off += b
            with self._mu:
                self._stats["queries"] += len(batch)
                self._stats["batches"] += 1
                self._stats["batched_rows"] += qcat.shape[0]
        except BaseException as e:  # noqa: BLE001 — propagate per ticket
            for t in batch:
                t._fail(e)

    def _refresh_cache(self):
        """Device-resident epoch-versioned center cache (dispatch-thread
        private). A stale epoch re-uploads into the same bucket shapes;
        only a center count crossing the power-of-two bucket boundary
        changes the operand signature (one warmup compile per bucket)."""
        with self._mu:
            epoch, centers = self._epoch, self._centers
        if epoch != self._cache_epoch:
            count = centers.shape[0]
            if count == 0:
                raise RuntimeError(
                    "no centers yet — submit_points + drain before assign")
            mcap = _pow2_at_least(count, self._center_bucket_min)
            host = np.full((mcap, self._d), 1e18, np.float32)
            host[:count] = centers
            mask = np.zeros((mcap,), np.float32)
            mask[:count] = 1.0
            grew = mcap != self._cache_mcap
            self._cache_buf = jnp.asarray(host)
            self._cache_mask = jnp.asarray(mask)
            self._cache_epoch, self._cache_mcap = epoch, mcap
            with self._mu:
                self._stats["cache_refreshes"] += 1
                if grew:
                    self._stats["bucket_growths"] += 1
        return self._cache_buf, self._cache_mask, self._cache_epoch

    def _dispatch(self, q: np.ndarray):
        """Run one coalesced micro-batch through the bucketed query
        program: pad to the power-of-two row bucket (max_batch-sized
        slices for oversized requests), one ``ops.assign_bucketed`` call
        per slice, results sliced back to the real rows."""
        buf, mask, epoch = self._refresh_cache()
        b = q.shape[0]
        out_i = np.empty((b,), np.int32)
        out_d = np.empty((b,), np.float32)
        for start in range(0, b, self._max_batch):
            blk = q[start:start + self._max_batch]
            nb = blk.shape[0]
            # pow2 bucket, capped at max_batch (itself a fixed shape even
            # when not a power of two) — O(log max_batch) signatures total
            bq = min(_pow2_at_least(nb, self._min_bucket), self._max_batch)
            qp = np.zeros((bq, self._d), np.float32)
            qp[:nb] = blk
            idx, d2 = ops.assign_bucketed(jnp.asarray(qp), buf, mask,
                                          impl=self._impl, chunk=self._chunk)
            out_i[start:start + nb] = np.asarray(idx)[:nb]
            out_d[start:start + nb] = np.asarray(d2)[:nb]
        return out_i, out_d, epoch
