"""Token sampling: greedy / temperature / top-k / top-p (nucleus).

Pure functions over (logits (B,V), key) — jit-safe, vmapped over batch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG = jnp.finfo(F32).min


def _apply_top_k(logits, k: int):
    if k <= 0:
        return logits
    kth = jnp.sort(logits, axis=-1)[..., -k][..., None]
    return jnp.where(logits < kth, NEG, logits)


def _apply_top_p(logits, p: float):
    if p >= 1.0:
        return logits
    sort_idx = jnp.argsort(-logits, axis=-1)
    sorted_logits = jnp.take_along_axis(logits, sort_idx, -1)
    probs = jax.nn.softmax(sorted_logits, -1)
    cum = jnp.cumsum(probs, -1)
    # keep tokens until cumulative prob exceeds p (always keep the first)
    keep_sorted = jnp.roll(cum, 1, axis=-1) < p
    keep_sorted = keep_sorted.at[..., 0].set(True)
    keep = jnp.zeros_like(keep_sorted).at[
        jnp.arange(logits.shape[0])[:, None], sort_idx].set(keep_sorted)
    return jnp.where(keep, logits, NEG)


def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0,
           top_p: float = 1.0):
    """logits (B,V) -> token ids (B,) int32."""
    logits = logits.astype(F32)
    if temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    logits = logits / temperature
    logits = _apply_top_k(logits, top_k)
    logits = _apply_top_p(logits, top_p)
    return jax.random.categorical(key, logits, -1).astype(jnp.int32)
