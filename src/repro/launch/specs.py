"""Abstract input specs (ShapeDtypeStruct) for every (arch × shape) cell.

No device allocation anywhere — these are the stand-ins the dry-run
lowers against. Modality frontends are stubs: frames / patch embeddings
arrive as precomputed float arrays, exactly as the assignment specifies.

Multi-process: every shape here is a *global* shape (``B`` is the global
batch), so specs built on one process describe the whole cluster's
program — they are device-free by construction and never consult
``jax.devices()``. Partitioning global shapes over processes is the mesh
layer's job: build the mesh with ``launch.mesh.make_cluster_mesh`` (or
``make_mesh(devices=jax.devices())``) so the ``repro.sharding`` spec
rules resolve axis sizes against the global device grid, not this
process's local subset.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec, get_config
from repro.models import init_cache, init_params
from repro.models.config import ModelConfig
from repro.optim import make_optimizer, make_schedule


def batch_specs(cfg: ModelConfig, B: int, S: int, *, train: bool) -> Dict[str, Any]:
    s: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if train:
        s["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "encdec":
        s["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                           jnp.float32)
    if cfg.family == "vlm":
        s["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), jnp.float32)
    return s


def params_specs(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(init_params, cfg=cfg), key)


def make_opt(cfg: ModelConfig, total_steps: int = 10_000):
    sched = "wsd" if cfg.name.startswith("minicpm") else "cosine"
    lr_fn = make_schedule(sched, peak=3e-4, warmup=200, total=total_steps)
    return make_optimizer(cfg.optimizer, lr_fn)


def state_specs(cfg: ModelConfig):
    params = params_specs(cfg)
    opt = make_opt(cfg)
    opt_state = jax.eval_shape(opt.init, params)
    return {"params": params, "opt": opt_state,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_specs(cfg: ModelConfig, B: int, S_max: int):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, B, S_max))


def input_specs(arch: str, shape: ShapeSpec,
                *, smoke: bool = False) -> Tuple[ModelConfig, Dict[str, Any]]:
    """Everything the step function for this cell consumes, as abstract
    specs: (cfg, {kind-specific inputs})."""
    cfg = get_config(arch, smoke=smoke)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return cfg, {
            "state": state_specs(cfg),
            "batch": batch_specs(cfg, B, S, train=True),
        }
    if shape.kind == "prefill":
        return cfg, {
            "params": params_specs(cfg),
            "batch": batch_specs(cfg, B, S, train=False),
        }
    # decode: one new token against an S-long cache
    return cfg, {
        "params": params_specs(cfg),
        "cache": cache_specs(cfg, B, S),
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
    }
