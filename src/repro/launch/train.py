"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt --resume auto

Production behaviors (DESIGN.md §6), all exercised by tests:
  * checkpoint/restart: atomic checkpoints every --ckpt-every steps;
    ``--resume auto`` restarts from the newest valid checkpoint; the data
    pipeline is a pure function of (seed, step), so the token stream
    resumes exactly.
  * restart policy: step exceptions (device loss, injected faults) trigger
    reload-from-checkpoint with bounded retries + backoff.
  * straggler watchdog: per-step wall-time is tracked; steps slower than
    ``factor ×`` the running median are counted and logged (on a real pod
    this signal feeds the controller's hot-swap decision).
  * elastic mesh: the mesh is rebuilt from the live device count on every
    (re)start; checkpoints are logical (host) arrays, so a smaller mesh
    reshards at load.
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import model_batch
from repro.launch.mesh import make_elastic_mesh
from repro.optim import make_optimizer, make_schedule
from repro.sharding import use_mesh
from repro.train import init_train_state, make_train_step


class StragglerWatchdog:
    """Flags steps slower than ``factor`` × running median."""

    def __init__(self, factor: float = 3.0, warmup: int = 3):
        self.times = []
        self.factor = factor
        self.warmup = warmup
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) <= self.warmup:
            return False
        med = float(np.median(self.times[self.warmup:]))
        if dt > self.factor * med:
            self.flagged += 1
            return True
        return False


class RestartPolicy:
    def __init__(self, max_restarts: int = 3, backoff_s: float = 0.5):
        self.max_restarts = max_restarts
        self.backoff_s = backoff_s
        self.restarts = 0

    def should_restart(self) -> bool:
        if self.restarts >= self.max_restarts:
            return False
        self.restarts += 1
        time.sleep(self.backoff_s * self.restarts)
        return True


def train_loop(cfg, *, steps: int, batch_size: int, seq_len: int,
               ckpt_dir: Optional[str] = None, ckpt_every: int = 20,
               resume: str = "none", seed: int = 0,
               log_every: int = 10,
               fault_hook: Optional[Callable[[int], None]] = None,
               policy: Optional[RestartPolicy] = None,
               watchdog: Optional[StragglerWatchdog] = None,
               mesh=None, lr: float = 3e-4,
               eval_every: int = 0, metrics_path: Optional[str] = None):
    """Runs training with restart-on-failure. Returns (state, history)."""
    policy = policy or RestartPolicy()
    watchdog = watchdog or StragglerWatchdog()
    sched = "wsd" if cfg.name.startswith("minicpm") else "cosine"
    opt = make_optimizer(cfg.optimizer,
                         make_schedule(sched, peak=lr, warmup=max(steps // 10, 1),
                                       total=steps))
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    history = []
    from repro.train.metrics import MetricsLogger, make_eval_fn
    logger = MetricsLogger(metrics_path)
    eval_fn = make_eval_fn(cfg, batch_size=batch_size, seq_len=seq_len,
                           seed=seed) if eval_every else None

    def fresh_state():
        return init_train_state(jax.random.PRNGKey(seed), cfg, opt)

    def load_or_init():
        if ckpt_dir and resume in ("auto", "must") and \
                latest_step(ckpt_dir) is not None:
            template = jax.tree.map(np.asarray, fresh_state())
            step, host_state = restore_checkpoint(ckpt_dir, template)
            state = jax.tree.map(jax.numpy.asarray, host_state)
            print(f"[train] resumed from step {step}")
            return state, step
        if resume == "must":
            raise FileNotFoundError("resume=must but no checkpoint found")
        return fresh_state(), 0

    with use_mesh(mesh):
        state, start = load_or_init()
        step = start
        while step < steps:
            try:
                if fault_hook is not None:
                    fault_hook(step)
                batch = model_batch(cfg, batch_size, seq_len, seed=seed,
                                    step=step)
                t0 = time.time()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                slow = watchdog.observe(dt)
                if step % log_every == 0 or slow:
                    print(f"[train] step={step} loss={loss:.4f} "
                          f"dt={dt*1e3:.0f}ms"
                          + (" STRAGGLER" if slow else ""), flush=True)
                history.append({"step": step, "loss": loss, "dt": dt})
                logger.log(step, loss=loss, dt=dt,
                           grad_norm=metrics.get("grad_norm", 0.0),
                           lr=metrics.get("lr", 0.0))
                if eval_fn and step and step % eval_every == 0:
                    ev = eval_fn(state["params"])
                    logger.log(step, **ev)
                    print(f"[eval] step={step} "
                          f"loss={ev['eval_loss']:.4f} "
                          f"ppl={ev['eval_ppl']:.2f}", flush=True)
                step += 1
                if ckpt_dir and step % ckpt_every == 0:
                    save_checkpoint(ckpt_dir, step, state)
            except (FloatingPointError, RuntimeError, ValueError) as e:
                print(f"[train] step {step} failed: {e!r}")
                if not policy.should_restart():
                    raise
                print(f"[train] restart {policy.restarts}/"
                      f"{policy.max_restarts} from checkpoint")
                state, step = load_or_init()
        if ckpt_dir:
            save_checkpoint(ckpt_dir, step, state)
    logger.close()
    return state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", default="none",
                    choices=["none", "auto", "must"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="none",
                    help="none | elastic | dxm grid like 2x1")
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--metrics", default=None, help="JSONL metrics path")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = None
    if args.mesh == "elastic":
        mesh = make_elastic_mesh()
    elif "x" in args.mesh:
        from repro.launch.mesh import make_mesh
        d, m = (int(v) for v in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
    _, hist = train_loop(
        cfg, steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume, seed=args.seed, mesh=mesh, lr=args.lr,
        eval_every=args.eval_every, metrics_path=args.metrics)
    if hist:
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        print(f"[train] done: loss {first:.4f} -> {last:.4f} "
              f"({len(hist)} steps)")


if __name__ == "__main__":
    main()
