import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell and extract the roofline terms from the compiled artifact.

The two lines above MUST run before any jax import (jax locks the device
count at first init); this module is the only place the placeholder-device
flag is set — tests/benchmarks see the real single device.

Per cell:
  * jit(step).lower(**input_specs).compile() under the production mesh
  * memory_analysis()  — per-device bytes (proves fit)
  * cost_analysis()    — HLO FLOPs / bytes for the compute & memory terms
  * HLO text parse     — collective operand bytes for the collective term
Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json and feed
EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro import compat
from repro.configs import ARCH_IDS, SHAPES, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, make_opt
from repro.models import decode_step, prefill
from repro.sharding import (batch_pspecs, cache_pspecs, params_pspecs,
                            shardings, state_pspecs, use_mesh)
from repro.train import make_train_step

# --- TPU v5e hardware constants (roofline denominators) -------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (~per chip, 1 link active)

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(?P<restype>.*?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _type_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str):
    """-> {name: [lines]} for each HLO computation; entry name too.

    Token-based header parse: computation headers are top-level lines
    ending in '{' containing '->'; tuple-typed signatures contain nested
    parens, so no regex over the parameter list.
    """
    comps, cur, entry = {}, None, None
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s and not line.startswith(" "):
            toks = s.split()
            name = toks[1] if toks[0] == "ENTRY" else toks[0]
            name = name.lstrip("%").split("(")[0]
            cur = comps.setdefault(name, [])
            if toks[0] == "ENTRY":
                entry = name
            continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            cur.append(line)
    return comps, entry


def _line_collective(line):
    m = _COLL_RE.search(line)
    if m is None or "-done(" in line:
        return None
    op = m.group("op")
    res_bytes = _type_bytes(m.group("restype"))
    g = 1
    gm = _GROUPS_LIST_RE.search(line)
    if gm:
        g = len(gm.group(1).split(","))
    else:
        gm = _GROUPS_IOTA_RE.search(line)
        if gm:
            g = int(gm.group(2))
    if op == "all-gather":
        operand = res_bytes // max(g, 1)
    elif op == "reduce-scatter":
        operand = res_bytes * max(g, 1)
    else:
        operand = res_bytes
    return op, operand


def parse_collectives(hlo_text: str):
    """Sum *operand* bytes of every collective in the (per-device,
    post-SPMD) HLO, multiplying collectives inside while-loop (scan)
    bodies by their trip counts — XLA prints each body once, so a naive
    line scan undercounts a 61-layer scanned stack by 61×.

    Trip count heuristic: largest integer constant compared in the loop's
    condition computation (how lax.scan lowers). Returns
    (total_operand_bytes, per_op dict).
    """
    comps, entry = _split_computations(hlo_text)
    # calls/whiles per computation
    call_re = re.compile(
        r"(?:calls=|to_apply=|body=|condition=)%?([\w\.\-]+)")

    def trip_count(cond_name: str) -> int:
        """Trip bound of a scan-lowered while: the s32 constant referenced
        by the condition's LT/GT compare (not just any constant)."""
        lines = comps.get(cond_name, [])
        consts = {}
        for line in lines:
            m = re.match(r"\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)",
                         line)
            if m:
                consts[m.group(1)] = int(m.group(2))
        for line in lines:
            if "compare(" in line and ("direction=LT" in line
                                       or "direction=GT" in line):
                for name in re.findall(r"%([\w\.\-]+)", line):
                    if name in consts:
                        return max(1, consts[name])
        # fallback: smallest plausible loop bound among s32 constants
        plausible = [v for v in consts.values() if 1 < v <= 4096]
        return min(plausible) if plausible else 1

    from functools import lru_cache

    import sys
    sys.setrecursionlimit(10000)

    @lru_cache(maxsize=None)
    def comp_cost(name: str):
        per_op = {}
        total = 0
        for line in comps.get(name, []):
            lc = _line_collective(line)
            if lc:
                op, operand = lc
                total += operand
                d = per_op.setdefault(op, [0, 0])
                d[0] += 1
                d[1] += operand
            if " while(" in line:
                m = _WHILE_RE.search(line)
                if m:
                    cond, body = m.group(1), m.group(2)
                    trips = trip_count(cond)
                    sub_total, sub_ops = comp_cost(body)
                    total += trips * sub_total
                    for op, (c, b) in sub_ops.items():
                        d = per_op.setdefault(op, [0, 0])
                        d[0] += trips * c
                        d[1] += trips * b
            else:
                for sub in call_re.findall(line):
                    if sub in comps and sub != name:
                        sub_total, sub_ops = comp_cost(sub)
                        total += sub_total
                        for op, (c, b) in sub_ops.items():
                            d = per_op.setdefault(op, [0, 0])
                            d[0] += c
                            d[1] += b
        return total, {k: tuple(v) for k, v in per_op.items()}

    total, per_op = comp_cost(entry) if entry else (0, {})
    return total, {k: {"count": c, "operand_bytes": b}
                   for k, (c, b) in per_op.items()}


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str):
    shape = SHAPES[shape_name]
    cfg, specs = input_specs(arch, shape)
    n_chips = mesh.size

    with use_mesh(mesh):
        if shape.kind == "train":
            opt = make_opt(cfg)
            step_fn = make_train_step(cfg, opt)
            st_sh = shardings(state_pspecs(specs["state"], mesh), mesh)
            b_sh = shardings(batch_pspecs(specs["batch"], mesh), mesh)
            fn = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                         donate_argnums=(0,))
            lowered = fn.lower(specs["state"], specs["batch"])
        elif shape.kind == "prefill":
            S_max = shape.seq_len
            p_sh = shardings(params_pspecs(specs["params"], mesh), mesh)
            b_sh = shardings(batch_pspecs(specs["batch"], mesh), mesh)

            def prefill_fn(params, batch):
                return prefill(params, batch, cfg, S_max)

            fn = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh))
            lowered = fn.lower(specs["params"], specs["batch"])
        else:  # decode
            p_sh = shardings(params_pspecs(specs["params"], mesh), mesh)
            c_sh = shardings(cache_pspecs(specs["cache"], mesh), mesh)
            t_sh = shardings(batch_pspecs(specs["token"], mesh), mesh)

            def decode_fn(params, cache, token):
                return decode_step(params, cache, token, cfg)

            fn = jax.jit(decode_fn, in_shardings=(p_sh, c_sh, t_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(specs["params"], specs["cache"],
                               specs["token"])
    return cfg, shape, lowered, n_chips


def model_flops(cfg, shape) -> float:
    n_active = cfg.param_counts()["active"]
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/seq


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str) -> dict:
    t0 = time.time()
    cfg, shape, lowered, n_chips = lower_cell(arch, shape_name, mesh,
                                              mesh_name)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = _mem_dict(compiled.memory_analysis())
    cost = {k: float(v) for k, v in compat.cost_analysis(compiled).items()
            if isinstance(v, (int, float))}
    hlo = compiled.as_text()
    coll_bytes_dev, per_op = parse_collectives(hlo)

    # cost_analysis on the partitioned executable is per-device.
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_bytes_dev / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / (flops_dev * n_chips) if flops_dev else 0.0
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_per_device": {"flops": flops_dev, "bytes": bytes_dev},
        "collectives_per_device": {"operand_bytes": coll_bytes_dev,
                                   "ops": per_op},
        "roofline_terms_s": terms,
        "dominant": dominant,
        "model_flops_global": mf,
        "useful_flops_fraction": useful,
        "hlo_bytes_global": bytes_dev * n_chips,
        "hlo_flops_global": flops_dev * n_chips,
        "collective_bytes_global": coll_bytes_dev * n_chips,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for multi in meshes:
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        mesh = make_production_mesh(multi_pod=multi)
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for arch in archs:
            for shape_name in shapes:
                if not shape_applicable(arch, shape_name):
                    continue
                path = os.path.join(outdir, f"{arch}__{shape_name}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {mesh_name} {arch} {shape_name}")
                    continue
                print(f"[cell] {mesh_name} {arch} {shape_name} ...",
                      flush=True)
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    # executables + HLO text accumulate in the pjit cache;
                    # 64 cells would exhaust host RAM without this.
                    jax.clear_caches()
                    import gc
                    gc.collect()
                    t = rec["roofline_terms_s"]
                    print(f"  ok lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"dom={rec['dominant']} "
                          f"comp={t['compute_s']:.3e} "
                          f"mem={t['memory_s']:.3e} "
                          f"coll={t['collective_s']:.3e} "
                          f"temp={rec['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB",
                          flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((mesh_name, arch, shape_name, repr(e)))
                    print(f"  FAIL {e!r}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", *f)
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
