"""Multi-process (multi-controller) cluster launcher.

This is the step that turns the repo's "distributed" path into a
distributed system: ``launch_cluster`` spawns N local worker processes,
each of which calls ``jax.distributed.initialize`` against a shared
coordinator (process 0's address/port), loads a named *scenario*
function, and runs it SPMD — every process executes the same driver over
the global mesh while holding only its own shard
(``ProcessShardedSource.for_process``). That is the paper's MapReduce
machine model made literal: machines hold their partition, rounds
exchange O(k) candidates, and no host ever materializes n rows.

Worker protocol
---------------

Workers are ``python -m repro.launch.cluster --worker ...``. Bootstrap
order is deliberate: the scenario module is imported *before*
``jax.distributed.initialize`` (an import-time failure is a
"died pre-initialize" fault the parent must surface, not hang on), then
the runtime comes up (CPU collectives selected via
``compat.distributed_initialize`` — without the gloo backend,
multi-process CPU programs fail at the first collective), then the
scenario runs with a ``WorkerContext``. Whatever JSON-serializable dict
it returns is printed as one ``CLUSTER-VERDICT {...}`` line on stdout —
the only parent↔child channel is the pipe, so there is nothing to clean
up after a hard kill. Exceptions at any stage become an ``ok: false``
verdict carrying the traceback, and a nonzero exit.

Parent lifecycle
----------------

``launch_cluster`` reads every worker's pipe from a drain thread (no
pipe-full deadlocks), optionally teeing to per-process log files (CI
uploads them as artifacts), and enforces two deadlines: a hard
``timeout`` after which every survivor is SIGKILLed (a hung collective
cannot block CI), and an early-exit rule — the moment any worker exits
nonzero, the rest get a short grace period (their own tracebacks beat
"killed" in a failure report) and are then killed. ``run_scenario``
wraps this for tests: it returns the per-process verdicts or raises
``ClusterError`` whose message carries each failed child's traceback.

Demo: ``PYTHONPATH=src python -m repro.launch.cluster --demo -n 2`` runs
a genuine 2-process ``mrg`` over per-process synthetic shards on
localhost and prints each process's verdict.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

VERDICT_PREFIX = "CLUSTER-VERDICT "
_SRC_DIR = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


# ---------------------------------------------------------------------------
# parent side — spawn, drain, deadline, collect
# ---------------------------------------------------------------------------


def free_port() -> int:
    """An OS-assigned free TCP port on localhost (bound momentarily, then
    released for the coordinator to claim)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@dataclass
class WorkerResult:
    """One worker's outcome: exit status, parsed verdict, raw output."""
    process_id: int
    returncode: Optional[int]
    verdict: Optional[dict]
    output: str
    timed_out: bool = False
    killed: bool = False

    @property
    def ok(self) -> bool:
        return (self.returncode == 0 and self.verdict is not None
                and bool(self.verdict.get("ok", False)))


class ClusterError(RuntimeError):
    """A cluster run failed; the message carries every failed worker's
    traceback (or output tail), and ``results`` the full per-process
    records."""

    def __init__(self, message: str, results: Sequence[WorkerResult]):
        super().__init__(message)
        self.results = list(results)


def worker_env(num_local_devices: int = 1,
               extra: Optional[dict] = None) -> dict:
    """Environment for one worker: pin the per-process CPU device count
    (both the modern ``JAX_NUM_CPU_DEVICES`` spelling and the
    ``XLA_FLAGS`` one the 0.4.x line honors) so the cluster topology is
    ``num_processes × num_local_devices`` regardless of host cores."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    flag = f"--xla_force_host_platform_device_count={num_local_devices}"
    env["XLA_FLAGS"] = (flags + " " + flag).strip()
    env["JAX_NUM_CPU_DEVICES"] = str(num_local_devices)
    env["PYTHONPATH"] = _SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
    env["PYTHONUNBUFFERED"] = "1"
    if extra:
        env.update(extra)
    return env


def _drain(pipe, lines: list, log_fh) -> None:
    for line in iter(pipe.readline, ""):
        lines.append(line)
        if log_fh is not None:
            log_fh.write(line)
            log_fh.flush()
    pipe.close()


def _tail(text: str, n: int = 30) -> str:
    return "".join(text.splitlines(keepends=True)[-n:])


def launch_cluster(target: str, num_processes: int, *,
                   args: Optional[dict] = None,
                   timeout: float = 180.0,
                   coordinator_port: Optional[int] = None,
                   init_timeout: Optional[float] = None,
                   num_local_devices: int = 1,
                   env: Optional[dict] = None,
                   log_dir: Optional[str] = None,
                   early_exit_grace: float = 5.0) -> list:
    """Spawn ``num_processes`` workers running ``target`` and collect
    their verdicts. Returns a list of ``WorkerResult`` (process order);
    never raises on worker failure — ``run_scenario`` layers the
    raise-with-tracebacks policy on top.

    ``target`` is ``module:function`` or ``/path/to/file.py:function``.
    ``timeout`` is the hard wall-clock bound: survivors are SIGKILLed at
    the deadline (the "hard kill on hang"). The early-exit rule kills
    the stragglers ``early_exit_grace`` seconds after the first nonzero
    exit, so one crashed worker fails the run in seconds, not after the
    full timeout spent inside a dead collective.
    """
    if num_processes < 1:
        raise ValueError(f"num_processes must be >= 1, got {num_processes}")
    port = coordinator_port if coordinator_port is not None else free_port()
    coordinator = f"127.0.0.1:{port}"
    wenv = worker_env(num_local_devices, extra=env)
    if log_dir:
        os.makedirs(log_dir, exist_ok=True)

    procs, buffers, threads, log_fhs = [], [], [], []
    for pid in range(num_processes):
        cmd = [sys.executable, "-m", "repro.launch.cluster", "--worker",
               "--target", target, "--coordinator", coordinator,
               "--num-processes", str(num_processes),
               "--process-id", str(pid)]
        if args is not None:
            cmd += ["--args-json", json.dumps(args)]
        if init_timeout is not None:
            cmd += ["--init-timeout", str(init_timeout)]
        fh = (open(os.path.join(log_dir, f"worker-{pid}.log"), "w")
              if log_dir else None)
        p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True, env=wenv)
        lines: list = []
        t = threading.Thread(target=_drain, args=(p.stdout, lines, fh),
                             daemon=True)
        t.start()
        procs.append(p)
        buffers.append(lines)
        threads.append(t)
        log_fhs.append(fh)

    deadline = time.monotonic() + timeout
    timed_out = [False] * num_processes
    killed = [False] * num_processes
    grace_deadline = None
    while True:
        rcs = [p.poll() for p in procs]
        if all(rc is not None for rc in rcs):
            break
        now = time.monotonic()
        if any(rc is not None and rc != 0 for rc in rcs):
            if grace_deadline is None:
                grace_deadline = min(deadline, now + early_exit_grace)
            if now >= grace_deadline:
                for i, p in enumerate(procs):
                    if p.poll() is None:
                        p.kill()
                        killed[i] = True
                break
        if now >= deadline:
            for i, p in enumerate(procs):
                if p.poll() is None:
                    p.kill()
                    timed_out[i] = True
            break
        time.sleep(0.05)
    for p in procs:
        try:
            p.wait(timeout=10)
        except subprocess.TimeoutExpired:  # pragma: no cover - SIGKILL lag
            pass
    for t in threads:
        t.join(timeout=5)
    for fh in log_fhs:
        if fh is not None:
            fh.close()

    results = []
    for pid, (p, lines) in enumerate(zip(procs, buffers)):
        out = "".join(lines)
        verdict = None
        for line in reversed(out.splitlines()):
            if line.startswith(VERDICT_PREFIX):
                try:
                    verdict = json.loads(line[len(VERDICT_PREFIX):])
                except json.JSONDecodeError:
                    verdict = None
                break
        results.append(WorkerResult(pid, p.returncode, verdict, out,
                                    timed_out=timed_out[pid],
                                    killed=killed[pid]))
    return results


def run_scenario(target: str, num_processes: int, **kwargs) -> list:
    """``launch_cluster`` + the test policy: every worker must exit 0
    with an ``ok`` verdict, else raise ``ClusterError`` whose message
    surfaces each failed child's traceback. Returns the verdict dicts in
    process order on success."""
    results = launch_cluster(target, num_processes, **kwargs)
    if all(r.ok for r in results):
        return [r.verdict for r in results]
    parts = [f"cluster run of {target!r} failed "
             f"({sum(not r.ok for r in results)}/{len(results)} workers):"]
    for r in results:
        if r.ok:
            continue
        state = ("timed out (hard-killed)" if r.timed_out
                 else "killed after another worker failed" if r.killed
                 else f"exit {r.returncode}")
        parts.append(f"\n--- worker {r.process_id}: {state} ---")
        if r.verdict and r.verdict.get("traceback"):
            parts.append(r.verdict["traceback"].rstrip())
        elif r.output.strip():
            parts.append(_tail(r.output).rstrip())
        else:
            parts.append("(no output)")
    raise ClusterError("\n".join(parts), results)


# ---------------------------------------------------------------------------
# worker side — bootstrap, run, verdict
# ---------------------------------------------------------------------------


@dataclass
class WorkerContext:
    """What a scenario function receives: its coordinates in the cluster
    and the launcher's scenario arguments."""
    process_id: int
    num_processes: int
    coordinator_address: str
    args: dict = field(default_factory=dict)


def load_target(target: str) -> Callable:
    """Resolve ``module:function`` or ``/path/to/file.py:function``."""
    mod_part, sep, fn_name = target.rpartition(":")
    if not sep:
        raise ValueError(
            f"target {target!r} must be 'module:function' or "
            "'/path/to/file.py:function'")
    if mod_part.endswith(".py"):
        import importlib.util
        spec = importlib.util.spec_from_file_location("cluster_scenario",
                                                      mod_part)
        if spec is None or spec.loader is None:
            raise ImportError(f"cannot load scenario file {mod_part!r}")
        module = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(module)
    else:
        import importlib
        module = importlib.import_module(mod_part)
    fn = getattr(module, fn_name, None)
    if not callable(fn):
        raise AttributeError(
            f"{mod_part!r} has no callable {fn_name!r}")
    return fn


def _emit_verdict(payload: dict) -> None:
    print(VERDICT_PREFIX + json.dumps(payload), flush=True)


def _worker_main(ns: argparse.Namespace) -> int:
    try:
        # 1) Load the scenario *before* the distributed runtime comes up:
        #    import-time failures are the "died pre-initialize" fault
        #    class and must produce a traceback verdict immediately.
        fn = load_target(ns.target)
        # 2) Bring up the runtime (selects CPU collectives first — see
        #    compat.distributed_initialize).
        from repro import compat
        compat.distributed_initialize(ns.coordinator, ns.num_processes,
                                      ns.process_id,
                                      initialization_timeout=ns.init_timeout)
        ctx = WorkerContext(ns.process_id, ns.num_processes,
                            ns.coordinator,
                            json.loads(ns.args_json or "{}"))
        payload = fn(ctx) or {}
        payload.setdefault("ok", True)
        payload.setdefault("process_id", ns.process_id)
        _emit_verdict(payload)
        # Success path only: shutdown() is a distributed barrier, so a
        # worker whose scenario *raised* must skip it — its peers may be
        # wedged inside a dead collective, and the failure verdict (just
        # flushed, above for success / in the handler below for errors)
        # must reach the parent rather than hang behind the barrier.
        compat.distributed_shutdown()
        return 0
    except BaseException as e:  # noqa: BLE001 - the verdict IS the report
        _emit_verdict({"ok": False, "process_id": ns.process_id,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()})
        sys.stdout.flush()
        sys.stderr.flush()
        # Hard exit: jax.distributed.initialize registers an atexit
        # shutdown whose barrier would block a *failed* worker behind
        # peers wedged in a dead collective — the verdict is already on
        # the pipe, so skip atexit entirely.
        os._exit(1)


# ---------------------------------------------------------------------------
# built-in demo scenario — the README's 2-process quickstart
# ---------------------------------------------------------------------------


def demo_mrg(ctx: WorkerContext) -> dict:
    """Genuine multi-process MRG: each process holds one synthetic shard,
    the mesh spans every process's devices, and round 1 streams only the
    local shard — centers and radius come out identical on every process
    (the verdict lets the parent check)."""
    from repro.core import MeshExecutor, mrg
    from repro.data import ProcessShardedSource, synthetic_source
    from repro.launch.mesh import make_cluster_mesh

    n_per = int(ctx.args.get("n_per_process", 2048))
    k = int(ctx.args.get("k", 8))
    sizes = [n_per] * ctx.num_processes
    local = synthetic_source("unif", n_per, seed=ctx.process_id, d=3)
    source = ProcessShardedSource.for_process(local, sizes, ctx.process_id)
    mesh = make_cluster_mesh()
    ex = MeshExecutor(mesh, block_rows=512)
    res = mrg(source, k, executor=ex)
    return {"n": source.n, "k": k,
            "radius": float(np.sqrt(np.float64(res.radius2))),
            "centers": np.asarray(res.centers).tolist(),
            "rounds": res.rounds}


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="multi-process jax.distributed launcher")
    ap.add_argument("--worker", action="store_true",
                    help="(internal) run as a cluster worker")
    ap.add_argument("--demo", action="store_true",
                    help="run the built-in 2-process mrg demo")
    ap.add_argument("-n", "--num-processes", type=int, default=2)
    ap.add_argument("--target", default=None,
                    help="scenario as module:function or file.py:function")
    ap.add_argument("--coordinator", default=None,
                    help="(worker) coordinator host:port")
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--args-json", default=None)
    ap.add_argument("--init-timeout", type=float, default=None)
    ap.add_argument("--timeout", type=float, default=180.0)
    ns = ap.parse_args(argv)
    if ns.worker:
        if not ns.target or not ns.coordinator:
            ap.error("--worker requires --target and --coordinator")
        return _worker_main(ns)
    target = ns.target or "repro.launch.cluster:demo_mrg"
    if not ns.demo and ns.target is None:
        ap.error("pass --demo or --target")
    try:
        verdicts = run_scenario(target, ns.num_processes,
                                timeout=ns.timeout,
                                init_timeout=ns.init_timeout)
    except ClusterError as e:
        print(str(e), file=sys.stderr)
        return 1
    first = verdicts[0]
    agree = all(v.get("centers") == first.get("centers")
                and v.get("radius") == first.get("radius")
                for v in verdicts[1:])
    print(f"{ns.num_processes}-process {target}: "
          f"n={first.get('n')} k={first.get('k')} "
          f"radius={first.get('radius'):.4f} rounds={first.get('rounds')} "
          f"all-processes-agree={agree}")
    return 0 if agree else 1


if __name__ == "__main__":
    sys.exit(main())
