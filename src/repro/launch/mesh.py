"""Production mesh construction.

IMPORTANT: functions only — importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).

Version portability: mesh construction goes through ``repro.compat``
(``jax.sharding.AxisType`` exists only on jax 0.6+; on 0.4.x every axis is
implicitly auto — see the support matrix in ``repro/compat.py``).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 v5e pod mesh, or 2×16×16 across two pods.

    Uses the first prod(shape) devices, so a 256-chip mesh builds fine on
    a 512-placeholder-device dry-run platform.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    import numpy as np
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(devs)}")
    arr = np.asarray(devs[:n]).reshape(tuple(shape))
    return compat.make_mesh(arr, axes)


def make_elastic_mesh(model_parallel: int = 16,
                      devices: Optional[list] = None) -> Mesh:
    """Largest (data, model) grid over the *live* device set — the elastic
    restart path: a degraded pod (e.g. 448 of 512 chips) still yields a
    valid mesh; data-parallel size shrinks to fit (DESIGN.md §6)."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    mp = min(model_parallel, n)
    while n % mp:
        mp -= 1
    dp = n // mp
    import numpy as np
    arr = np.asarray(devices[: dp * mp]).reshape(dp, mp)
    return compat.make_mesh(arr, ("data", "model"))
