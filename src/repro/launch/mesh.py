"""Production mesh construction.

IMPORTANT: functions only — importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).

Version portability: mesh construction goes through ``repro.compat``
(``jax.sharding.AxisType`` exists only on jax 0.6+; on 0.4.x every axis is
implicitly auto — see the support matrix in ``repro/compat.py``).

Multi-process: under ``jax.distributed`` the full device set is
``jax.devices()`` (global, ordered process-major) while this process can
address only ``jax.local_devices()``. Meshes for SPMD programs must be
built over the *global* set — a mesh over local devices describes a
different (per-process) program on every controller, which is exactly the
bug class ``make_cluster_mesh`` exists to prevent. ``make_mesh`` therefore
takes an explicit ``devices=`` (defaulting to the global set) so callers
on one process can describe the whole cluster's mesh.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 v5e pod mesh, or 2×16×16 across two pods.

    Uses the first prod(shape) devices, so a 256-chip mesh builds fine on
    a 512-placeholder-device dry-run platform.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              *, devices: Optional[Sequence] = None) -> Mesh:
    """Mesh of the first prod(shape) devices of ``devices`` (default: the
    *global* ``jax.devices()`` — every process of a multi-process run
    builds the same mesh; pass ``jax.local_devices()`` explicitly only
    for deliberately per-process programs)."""
    import numpy as np
    n = int(np.prod(shape))
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(devs)}")
    arr = np.asarray(devs[:n]).reshape(tuple(shape))
    return compat.make_mesh(arr, axes)


def make_cluster_mesh(axes: Sequence[str] = ("data",)) -> Mesh:
    """The canonical multi-process data mesh: one axis spanning every
    device of every process, ordered process-major.

    Validates the global view so partition bugs fail at construction
    rather than as silent per-process divergence:

      * the mesh covers *all* ``jax.devices()`` — never a local subset
        (``len == process_count · local_device_count``);
      * each process's devices form one contiguous run in process order,
        so dim-0 shard *s* of a ``P(axes)``-sharded array is owned by
        process ``s // local_device_count`` — the contract
        ``ProcessShardedSource.for_process`` and the streamed
        ``MeshExecutor`` rely on.

    Single-process this degenerates to a mesh over all local devices —
    the same object ``make_mesh((len(devices),), axes)`` builds — so
    scenario code is identical on 1 and N processes.
    """
    import numpy as np
    devs = jax.devices()
    pc = compat.process_count()
    per = len(devs) // pc
    if per * pc != len(devs):
        raise ValueError(
            f"{len(devs)} global devices do not divide evenly over "
            f"{pc} processes")
    for i, d in enumerate(devs):
        if d.process_index != i // per:
            raise ValueError(
                f"global device order is not process-major: device {i} "
                f"belongs to process {d.process_index}, expected "
                f"{i // per} — build the mesh from an explicitly "
                "reordered device list instead")
    arr = np.asarray(devs)
    axes = tuple(axes)
    if len(axes) != 1:
        raise ValueError(
            f"make_cluster_mesh builds a single sharding axis, got {axes}")
    return compat.make_mesh(arr, axes)


def make_elastic_mesh(model_parallel: int = 16,
                      devices: Optional[list] = None) -> Mesh:
    """Largest (data, model) grid over the *live* device set — the elastic
    restart path: a degraded pod (e.g. 448 of 512 chips) still yields a
    valid mesh; data-parallel size shrinks to fit (DESIGN.md §6)."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    mp = min(model_parallel, n)
    while n % mp:
        mp -= 1
    dp = n // mp
    import numpy as np
    arr = np.asarray(devices[: dp * mp]).reshape(dp, mp)
    return compat.make_mesh(arr, ("data", "model"))
