"""jax version-compatibility shim — the single import surface for API drift.

Every jax API that moved, was renamed, or changed signature between the
0.4.x and 0.6+ lines is resolved here once, so the rest of the codebase
imports from ``repro.compat`` and never version-checks inline.

Support matrix (verified against the pinned CI versions):

  =====================  =======================  =========================
  capability             jax 0.4.x (>=0.4.30)     jax 0.6+
  =====================  =======================  =========================
  shard_map              jax.experimental.        ``jax.shard_map`` with
                         shard_map.shard_map      ``check_vma=``
                         with ``check_rep=``
  mesh axis types        (not available; meshes   ``jax.sharding.AxisType``
                         are implicitly "auto")   passed via ``axis_types=``
  ambient mesh context   legacy ``with mesh:``    ``jax.set_mesh(mesh)``
                         resource-env manager
  cost_analysis()        one-element list of      flat dict
                         dicts
  global array assembly  jax.make_array_from_     same API (stable); the
                         single_device_arrays     helper additionally
                                                  feature-detects and falls
                                                  back to a sharded
                                                  ``device_put``
  multi-process init     jax.distributed.         same API; CPU collectives
                         initialize (CPU          selected the same way
                         collectives via the      (feature-detected — absent
                         ``jax_cpu_collectives_   flag is skipped, never an
                         implementation`` flag)   error)
  cross-process fetch    jax.experimental.        same API (stable); the
                         multihost_utils.         wrappers add the single-
                         process_allgather        process fast paths
  =====================  =======================  =========================

Everything here is feature-detected (``hasattr``), not version-compared:
point releases backport APIs and the jaxlib/jax pair may be mixed, so the
presence of the symbol is the only reliable signal.
"""
from __future__ import annotations

import contextlib
import functools
import inspect
from typing import Sequence

import jax
import jax.sharding
import numpy as np

HAS_TOP_LEVEL_SHARD_MAP = hasattr(jax, "shard_map")
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_GLOBAL_ASSEMBLY = hasattr(jax, "make_array_from_single_device_arrays")
HAS_DISTRIBUTED = hasattr(jax, "distributed") and hasattr(
    jax.distributed, "initialize")


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def _resolve_shard_map():
    """-> (callable, name of the replication-check kwarg it accepts)."""
    if HAS_TOP_LEVEL_SHARD_MAP:
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn  # 0.4.x
    params = inspect.signature(fn).parameters
    for kw in ("check_vma", "check_rep"):
        if kw in params:
            return fn, kw
    return fn, None  # neither: pass nothing (future-proof)


_SHARD_MAP, _CHECK_KW = _resolve_shard_map()


def shard_map(f=None, *, mesh, in_specs, out_specs, check_replication=True):
    """Version-portable ``shard_map``.

    ``check_replication`` maps onto ``check_vma=`` (jax >= 0.6) or
    ``check_rep=`` (jax 0.4.x experimental). Usable directly or as a
    decorator factory::

        @functools.partial(compat.shard_map, mesh=mesh,
                           in_specs=..., out_specs=...,
                           check_replication=False)
        def run(local): ...
    """
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if _CHECK_KW is not None:
        kwargs[_CHECK_KW] = check_replication
    if f is None:
        return functools.partial(_SHARD_MAP, **kwargs)
    return _SHARD_MAP(f, **kwargs)


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------

def make_mesh(devices, axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """``Mesh`` over an ndarray of devices with *auto* axis types.

    jax 0.6+ makes axis types explicit (``AxisType.Auto`` reproduces the
    0.4.x behavior); 0.4.x has no ``axis_types=`` kwarg and every axis is
    implicitly auto, so the two branches build the same mesh semantics.
    """
    axes = tuple(axis_names)
    if HAS_AXIS_TYPE:
        from jax.sharding import AxisType
        return jax.sharding.Mesh(devices, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
    return jax.sharding.Mesh(devices, axes)


# ---------------------------------------------------------------------------
# Ambient mesh context
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def set_mesh(mesh: jax.sharding.Mesh):
    """Install ``mesh`` as the ambient mesh for the duration.

    jax 0.6+: ``jax.set_mesh`` (explicit-sharding aware). jax 0.4.x: the
    legacy ``with mesh:`` resource-env context (sufficient for the
    NamedSharding / shard_map paths used in this codebase, which always
    pass the mesh explicitly as well).
    """
    if HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


# ---------------------------------------------------------------------------
# Sharded data feeding
# ---------------------------------------------------------------------------

def global_array_from_shards(mesh: jax.sharding.Mesh, pspec,
                             pieces: Sequence[np.ndarray]) -> jax.Array:
    """Assemble one global device array from per-shard *host* pieces.

    ``pieces`` is one host array per dim-0 shard of the sharding
    ``NamedSharding(mesh, pspec)``, all of equal shape, in shard order
    (= global row order). Each piece is ``device_put`` onto its own
    shard's device(s) and stitched with
    ``jax.make_array_from_single_device_arrays`` — per-shard DMA with no
    host-side staging buffer for the global array, which is the multi-host
    data-feeding pattern in single-process form (the per-shard transfers
    are asynchronous, so the sources' prefetch ring overlaps them with
    compute). Axes of ``mesh`` not named in ``pspec`` replicate each piece
    across their devices.

    The assembly API is stable across both supported jax lines; it is
    still feature-detected, with a host-concatenate + sharded
    ``device_put`` fallback, so this helper can never strand the streamed
    executors on an API-less build.

    **Multi-process:** under ``jax.distributed`` each process addresses
    only its own devices, so a piece whose shard lives on *another*
    process may be ``None`` — only the locally-addressable shards'
    pieces are ``device_put``, and ``make_array_from_single_device_arrays``
    assembles the global array from local shards alone (every process
    contributes its own). A ``None`` piece for a *locally addressable*
    shard is an error, as is any ``None`` on the concatenate fallback
    (which needs every row on this host).
    """
    arrs = [None if p is None else np.asarray(p) for p in pieces]
    ref = next((a for a in arrs if a is not None), None)
    if ref is None:
        raise ValueError(
            "all pieces are None — at least this process's own shards "
            "must be provided")
    rows = ref.shape[0]
    for i, a in enumerate(arrs):
        if a is not None and a.shape != ref.shape:
            raise ValueError(
                f"piece {i} has shape {a.shape}, expected {ref.shape} "
                "(pad every shard's piece to one common block shape)")
    shape = (rows * len(arrs),) + ref.shape[1:]
    sharding = jax.sharding.NamedSharding(mesh, pspec)
    if not HAS_GLOBAL_ASSEMBLY:  # pragma: no cover - both CI lines have it
        if any(a is None for a in arrs):
            raise RuntimeError(
                "the sharded device_put fallback concatenates on the host "
                "and needs every piece; None (remote) pieces require "
                "jax.make_array_from_single_device_arrays")
        return jax.device_put(np.concatenate(arrs, axis=0), sharding)
    shards = []
    for dev, idx in sharding.addressable_devices_indices_map(shape).items():
        start = idx[0].start or 0
        stop = idx[0].stop if idx[0].stop is not None else shape[0]
        if stop - start != rows or start % rows:
            raise ValueError(
                f"sharding splits dim 0 into [{start}, {stop}) slices; "
                f"expected one {rows}-row piece per shard — pass one piece "
                "per dim-0 shard of the pspec")
        piece = arrs[start // rows]
        if piece is None:
            raise ValueError(
                f"piece {start // rows} is None but its shard is "
                f"addressable from this process ({dev}) — only shards "
                "owned by other processes may omit their data")
        shards.append(jax.device_put(piece, dev))
    return jax.make_array_from_single_device_arrays(shape, sharding, shards)


# ---------------------------------------------------------------------------
# Multi-process (multi-controller) runtime
#
# Everything below is the compat surface for genuine ``jax.distributed``
# runs (repro/launch/cluster.py): initialization with CPU collectives
# selected, process topology queries, cross-process value exchange, and
# the sharding helpers the streamed MeshExecutor needs to know which
# shards this process feeds. All of it degrades to cheap single-process
# behavior when no cluster was initialized, so callers never branch on
# the runtime themselves.
# ---------------------------------------------------------------------------


def enable_cpu_collectives(impl: str = "gloo") -> bool:
    """Select the CPU cross-process collectives backend (default gloo).

    Must run *before* the CPU backend is first initialized — without it,
    multi-process programs on CPU fail with "Multiprocess computations
    aren't implemented on the CPU backend". The flag exists on both
    supported lines; feature-detected (an absent/renamed flag returns
    False rather than raising) because it is exactly the kind of
    config-surface drift this module exists to absorb.
    """
    try:
        jax.config.update("jax_cpu_collectives_implementation", impl)
        return True
    except (AttributeError, ValueError):  # pragma: no cover - drift guard
        return False


def distributed_initialize(coordinator_address: str, num_processes: int,
                           process_id: int, *,
                           initialization_timeout: float | None = None,
                           cpu_collectives: str | None = "gloo") -> None:
    """``jax.distributed.initialize`` with the version drift absorbed.

    Selects the CPU collectives backend first (set ``cpu_collectives=None``
    on accelerator clusters where XLA's native collectives apply), then
    initializes the distributed runtime. ``initialization_timeout`` is
    forwarded only where the jax line supports the kwarg — on lines
    without it the coordinator default applies.
    """
    if not HAS_DISTRIBUTED:  # pragma: no cover - both CI lines have it
        raise RuntimeError(
            "this jax build has no jax.distributed.initialize — "
            "multi-process execution is unavailable")
    if cpu_collectives is not None:
        enable_cpu_collectives(cpu_collectives)
    kwargs = dict(coordinator_address=coordinator_address,
                  num_processes=int(num_processes),
                  process_id=int(process_id))
    if initialization_timeout is not None:
        params = inspect.signature(jax.distributed.initialize).parameters
        if "initialization_timeout" in params:
            kwargs["initialization_timeout"] = int(initialization_timeout)
    jax.distributed.initialize(**kwargs)


def distributed_shutdown() -> None:
    """Tear down the distributed runtime; a no-op when none is active."""
    if HAS_DISTRIBUTED and hasattr(jax.distributed, "shutdown"):
        try:
            jax.distributed.shutdown()
        except RuntimeError:  # pragma: no cover - already down
            pass


def process_index() -> int:
    """This controller's process id (0 on single-process runtimes)."""
    return int(jax.process_index())


def process_count() -> int:
    """Number of controller processes (1 on single-process runtimes)."""
    return int(jax.process_count())


def fetch_global(arr) -> np.ndarray:
    """The full host value of a (possibly cross-process sharded) array.

    Single-process: a plain ``np.asarray`` — byte-identical to the
    pre-multi-process executors, so compiled programs and parity tests
    are untouched. Multi-process: ``multihost_utils.process_allgather``,
    which every process must call (it is a collective); the result is
    the same full value on every process.
    """
    if process_count() == 1:
        return np.asarray(arr)
    from jax.experimental import multihost_utils
    return np.asarray(multihost_utils.process_allgather(arr))


def exchange_host(x) -> np.ndarray:
    """All-gather a per-process *host* value: returns ``(P, ...)`` stacked
    in process order (row p is process p's contribution). Single-process:
    ``x[None]``. Every process must pass the same shape/dtype and every
    process must call (collective). This is the O(k) candidate exchange
    of the paper's MapReduce rounds — centers move, points never do.
    """
    x = np.asarray(x)
    if process_count() == 1:
        return x[None]
    from jax.experimental import multihost_utils
    out = np.asarray(multihost_utils.process_allgather(x, tiled=False))
    return out.reshape((process_count(),) + x.shape)


def replicated_array(mesh: jax.sharding.Mesh, x) -> jax.Array:
    """``x`` replicated across every device of ``mesh``.

    Single-process this is just ``device_put`` with a replicated
    ``NamedSharding``. Multi-process, ``device_put`` cannot target
    non-addressable devices on the 0.4.x line, so the replica set is
    assembled from per-local-device copies via
    ``make_array_from_single_device_arrays`` — every process holds the
    same host value (replicated-by-construction SPMD drivers), so no
    data crosses processes.
    """
    x = np.asarray(x)
    sharding = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    if process_count() == 1:
        return jax.device_put(x, sharding)
    if not HAS_GLOBAL_ASSEMBLY:  # pragma: no cover - both CI lines have it
        raise RuntimeError(
            "multi-process replication requires "
            "jax.make_array_from_single_device_arrays")
    local = [d for d in mesh.devices.flat
             if d.process_index == process_index()]
    arrs = [jax.device_put(x, d) for d in local]
    return jax.make_array_from_single_device_arrays(x.shape, sharding, arrs)


def local_shard_indices(mesh: jax.sharding.Mesh, pspec,
                        num_shards: int) -> list:
    """Which dim-0 shards of ``NamedSharding(mesh, pspec)`` this process
    addresses, as sorted shard indices in ``range(num_shards)``.

    This is how the streamed ``MeshExecutor`` decides which source shards
    to actually read in a multi-process run (the others are fed by their
    owning processes). Computed from the sharding's addressable-device
    index map over a one-row-per-shard probe shape, so it tracks whatever
    device order the mesh was built with.
    """
    sharding = jax.sharding.NamedSharding(mesh, pspec)
    shape = (int(num_shards), 1)
    out = set()
    for _, idx in sharding.addressable_devices_indices_map(shape).items():
        start = idx[0].start or 0
        stop = idx[0].stop if idx[0].stop is not None else num_shards
        out.update(range(start, stop))
    return sorted(out)


# ---------------------------------------------------------------------------
# Compiled-executable introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """Flat cost dict from a compiled executable.

    jax 0.4.x returns a one-element list of dicts (one per program);
    0.6+ returns the dict directly. Empty dict when unavailable.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
