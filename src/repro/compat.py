"""jax version-compatibility shim — the single import surface for API drift.

Every jax API that moved, was renamed, or changed signature between the
0.4.x and 0.6+ lines is resolved here once, so the rest of the codebase
imports from ``repro.compat`` and never version-checks inline.

Support matrix (verified against the pinned CI versions):

  =====================  =======================  =========================
  capability             jax 0.4.x (>=0.4.30)     jax 0.6+
  =====================  =======================  =========================
  shard_map              jax.experimental.        ``jax.shard_map`` with
                         shard_map.shard_map      ``check_vma=``
                         with ``check_rep=``
  mesh axis types        (not available; meshes   ``jax.sharding.AxisType``
                         are implicitly "auto")   passed via ``axis_types=``
  ambient mesh context   legacy ``with mesh:``    ``jax.set_mesh(mesh)``
                         resource-env manager
  cost_analysis()        one-element list of      flat dict
                         dicts
  =====================  =======================  =========================

Everything here is feature-detected (``hasattr``), not version-compared:
point releases backport APIs and the jaxlib/jax pair may be mixed, so the
presence of the symbol is the only reliable signal.
"""
from __future__ import annotations

import contextlib
import functools
import inspect
from typing import Sequence

import jax
import jax.sharding

HAS_TOP_LEVEL_SHARD_MAP = hasattr(jax, "shard_map")
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax, "set_mesh")


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def _resolve_shard_map():
    """-> (callable, name of the replication-check kwarg it accepts)."""
    if HAS_TOP_LEVEL_SHARD_MAP:
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn  # 0.4.x
    params = inspect.signature(fn).parameters
    for kw in ("check_vma", "check_rep"):
        if kw in params:
            return fn, kw
    return fn, None  # neither: pass nothing (future-proof)


_SHARD_MAP, _CHECK_KW = _resolve_shard_map()


def shard_map(f=None, *, mesh, in_specs, out_specs, check_replication=True):
    """Version-portable ``shard_map``.

    ``check_replication`` maps onto ``check_vma=`` (jax >= 0.6) or
    ``check_rep=`` (jax 0.4.x experimental). Usable directly or as a
    decorator factory::

        @functools.partial(compat.shard_map, mesh=mesh,
                           in_specs=..., out_specs=...,
                           check_replication=False)
        def run(local): ...
    """
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if _CHECK_KW is not None:
        kwargs[_CHECK_KW] = check_replication
    if f is None:
        return functools.partial(_SHARD_MAP, **kwargs)
    return _SHARD_MAP(f, **kwargs)


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------

def make_mesh(devices, axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """``Mesh`` over an ndarray of devices with *auto* axis types.

    jax 0.6+ makes axis types explicit (``AxisType.Auto`` reproduces the
    0.4.x behavior); 0.4.x has no ``axis_types=`` kwarg and every axis is
    implicitly auto, so the two branches build the same mesh semantics.
    """
    axes = tuple(axis_names)
    if HAS_AXIS_TYPE:
        from jax.sharding import AxisType
        return jax.sharding.Mesh(devices, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
    return jax.sharding.Mesh(devices, axes)


# ---------------------------------------------------------------------------
# Ambient mesh context
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def set_mesh(mesh: jax.sharding.Mesh):
    """Install ``mesh`` as the ambient mesh for the duration.

    jax 0.6+: ``jax.set_mesh`` (explicit-sharding aware). jax 0.4.x: the
    legacy ``with mesh:`` resource-env context (sufficient for the
    NamedSharding / shard_map paths used in this codebase, which always
    pass the mesh explicitly as well).
    """
    if HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


# ---------------------------------------------------------------------------
# Compiled-executable introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """Flat cost dict from a compiled executable.

    jax 0.4.x returns a one-element list of dicts (one per program);
    0.6+ returns the dict directly. Empty dict when unavailable.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
