"""jax version-compatibility shim — the single import surface for API drift.

Every jax API that moved, was renamed, or changed signature between the
0.4.x and 0.6+ lines is resolved here once, so the rest of the codebase
imports from ``repro.compat`` and never version-checks inline.

Support matrix (verified against the pinned CI versions):

  =====================  =======================  =========================
  capability             jax 0.4.x (>=0.4.30)     jax 0.6+
  =====================  =======================  =========================
  shard_map              jax.experimental.        ``jax.shard_map`` with
                         shard_map.shard_map      ``check_vma=``
                         with ``check_rep=``
  mesh axis types        (not available; meshes   ``jax.sharding.AxisType``
                         are implicitly "auto")   passed via ``axis_types=``
  ambient mesh context   legacy ``with mesh:``    ``jax.set_mesh(mesh)``
                         resource-env manager
  cost_analysis()        one-element list of      flat dict
                         dicts
  global array assembly  jax.make_array_from_     same API (stable); the
                         single_device_arrays     helper additionally
                                                  feature-detects and falls
                                                  back to a sharded
                                                  ``device_put``
  =====================  =======================  =========================

Everything here is feature-detected (``hasattr``), not version-compared:
point releases backport APIs and the jaxlib/jax pair may be mixed, so the
presence of the symbol is the only reliable signal.
"""
from __future__ import annotations

import contextlib
import functools
import inspect
from typing import Sequence

import jax
import jax.sharding
import numpy as np

HAS_TOP_LEVEL_SHARD_MAP = hasattr(jax, "shard_map")
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_GLOBAL_ASSEMBLY = hasattr(jax, "make_array_from_single_device_arrays")


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def _resolve_shard_map():
    """-> (callable, name of the replication-check kwarg it accepts)."""
    if HAS_TOP_LEVEL_SHARD_MAP:
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn  # 0.4.x
    params = inspect.signature(fn).parameters
    for kw in ("check_vma", "check_rep"):
        if kw in params:
            return fn, kw
    return fn, None  # neither: pass nothing (future-proof)


_SHARD_MAP, _CHECK_KW = _resolve_shard_map()


def shard_map(f=None, *, mesh, in_specs, out_specs, check_replication=True):
    """Version-portable ``shard_map``.

    ``check_replication`` maps onto ``check_vma=`` (jax >= 0.6) or
    ``check_rep=`` (jax 0.4.x experimental). Usable directly or as a
    decorator factory::

        @functools.partial(compat.shard_map, mesh=mesh,
                           in_specs=..., out_specs=...,
                           check_replication=False)
        def run(local): ...
    """
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if _CHECK_KW is not None:
        kwargs[_CHECK_KW] = check_replication
    if f is None:
        return functools.partial(_SHARD_MAP, **kwargs)
    return _SHARD_MAP(f, **kwargs)


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------

def make_mesh(devices, axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """``Mesh`` over an ndarray of devices with *auto* axis types.

    jax 0.6+ makes axis types explicit (``AxisType.Auto`` reproduces the
    0.4.x behavior); 0.4.x has no ``axis_types=`` kwarg and every axis is
    implicitly auto, so the two branches build the same mesh semantics.
    """
    axes = tuple(axis_names)
    if HAS_AXIS_TYPE:
        from jax.sharding import AxisType
        return jax.sharding.Mesh(devices, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
    return jax.sharding.Mesh(devices, axes)


# ---------------------------------------------------------------------------
# Ambient mesh context
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def set_mesh(mesh: jax.sharding.Mesh):
    """Install ``mesh`` as the ambient mesh for the duration.

    jax 0.6+: ``jax.set_mesh`` (explicit-sharding aware). jax 0.4.x: the
    legacy ``with mesh:`` resource-env context (sufficient for the
    NamedSharding / shard_map paths used in this codebase, which always
    pass the mesh explicitly as well).
    """
    if HAS_SET_MESH:
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


# ---------------------------------------------------------------------------
# Sharded data feeding
# ---------------------------------------------------------------------------

def global_array_from_shards(mesh: jax.sharding.Mesh, pspec,
                             pieces: Sequence[np.ndarray]) -> jax.Array:
    """Assemble one global device array from per-shard *host* pieces.

    ``pieces`` is one host array per dim-0 shard of the sharding
    ``NamedSharding(mesh, pspec)``, all of equal shape, in shard order
    (= global row order). Each piece is ``device_put`` onto its own
    shard's device(s) and stitched with
    ``jax.make_array_from_single_device_arrays`` — per-shard DMA with no
    host-side staging buffer for the global array, which is the multi-host
    data-feeding pattern in single-process form (the per-shard transfers
    are asynchronous, so the sources' prefetch ring overlaps them with
    compute). Axes of ``mesh`` not named in ``pspec`` replicate each piece
    across their devices.

    The assembly API is stable across both supported jax lines; it is
    still feature-detected, with a host-concatenate + sharded
    ``device_put`` fallback, so this helper can never strand the streamed
    executors on an API-less build.
    """
    arrs = [np.asarray(p) for p in pieces]
    rows = arrs[0].shape[0]
    for i, a in enumerate(arrs):
        if a.shape != arrs[0].shape:
            raise ValueError(
                f"piece {i} has shape {a.shape}, expected {arrs[0].shape} "
                "(pad every shard's piece to one common block shape)")
    shape = (rows * len(arrs),) + arrs[0].shape[1:]
    sharding = jax.sharding.NamedSharding(mesh, pspec)
    if not HAS_GLOBAL_ASSEMBLY:  # pragma: no cover - both CI lines have it
        return jax.device_put(np.concatenate(arrs, axis=0), sharding)
    shards = []
    for dev, idx in sharding.addressable_devices_indices_map(shape).items():
        start = idx[0].start or 0
        stop = idx[0].stop if idx[0].stop is not None else shape[0]
        if stop - start != rows or start % rows:
            raise ValueError(
                f"sharding splits dim 0 into [{start}, {stop}) slices; "
                f"expected one {rows}-row piece per shard — pass one piece "
                "per dim-0 shard of the pspec")
        shards.append(jax.device_put(arrs[start // rows], dev))
    return jax.make_array_from_single_device_arrays(shape, sharding, shards)


# ---------------------------------------------------------------------------
# Compiled-executable introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """Flat cost dict from a compiled executable.

    jax 0.4.x returns a one-element list of dicts (one per program);
    0.6+ returns the dict directly. Empty dict when unavailable.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
