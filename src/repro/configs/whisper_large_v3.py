"""whisper-large-v3 — enc-dec audio backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        num_layers=32, enc_layers=32, d_model=1280, num_heads=20,
        num_kv_heads=20, d_ff=5120, vocab_size=51866, head_dim=64,
        qkv_bias=True, rope_type="sinusoidal",
        norm="layernorm", act="gelu", tie_embeddings=True,
        enc_seq=1500, frontend="audio_stub",
        remat="full",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="whisper-smoke", num_layers=2, enc_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        enc_seq=16, param_dtype="float32", compute_dtype="float32",
        remat="none",
    )
