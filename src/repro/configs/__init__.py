"""Assigned-architecture configs (+ reduced smoke variants)."""
from .common import (  # noqa: F401
    ARCH_IDS,
    LONG_CONTEXT_ARCHS,
    SHAPES,
    ShapeSpec,
    get_config,
    live_cells,
    shape_applicable,
)
