"""minicpm-2b — llama-like dense with WSD schedule + μP-style scalings
[arXiv:2404.06395; hf]. residual_scale = 1.4/sqrt(L); logit_scale =
256/d_model (hidden-dim base 256)."""
import math

from repro.models.config import ModelConfig

_L = 40
_D = 2304


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        num_layers=_L, d_model=_D, num_heads=36, num_kv_heads=36,
        d_ff=5760, vocab_size=122753, head_dim=64,
        norm="rmsnorm", act="silu", tie_embeddings=True,
        residual_scale=1.4 / math.sqrt(_L), logit_scale=256.0 / _D,
        remat="full",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="minicpm-2b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        residual_scale=1.4 / math.sqrt(2), logit_scale=1.0,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
