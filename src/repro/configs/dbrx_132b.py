"""dbrx-132b — fine-grained MoE, 16 experts top-4
[hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=10752, vocab_size=100352, head_dim=128,
        num_experts=16, top_k=4,
        norm="rmsnorm", act="silu", tie_embeddings=False,
        optimizer="adafactor", remat="full",
        remat_block=8, microbatches=2, accum_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="dbrx-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=512,
        num_experts=4, top_k=2,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
