"""granite-3-2b — dense GQA kv=8 [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b", family="dense",
        num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
        d_ff=8192, vocab_size=49155, head_dim=64,
        norm="rmsnorm", act="silu", tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="granite-3-2b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
