"""olmo-1b — dense MHA, non-parametric LayerNorm [arXiv:2402.00838; hf]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b", family="dense",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=50304, head_dim=128,
        norm="layernorm_np", act="silu", tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="olmo-1b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
