"""qwen2-0.5b — dense GQA (kv=2), QKV bias [arXiv:2407.10671; hf]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        d_ff=4864, vocab_size=151936, head_dim=64,
        qkv_bias=True, rope_theta=1e6,
        norm="rmsnorm", act="silu", tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="qwen2-0.5b-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
