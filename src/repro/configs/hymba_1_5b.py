"""hymba-1.5b — hybrid: parallel attention + Mamba heads per layer, sliding
window with periodic global layers [arXiv:2411.13676; hf].

Deviation note (DESIGN.md): real Hymba has 3 global layers (first/middle/
last) + meta tokens; we use global_every=8 (layers 0,8,16,24) and no meta
tokens — same compute/memory class.
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        d_ff=5504, vocab_size=32001, head_dim=64,
        window=1024, global_every=8,
        ssm_state=16, ssm_head_dim=64, ssm_expand=2,
        norm="rmsnorm", act="silu", tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="hymba-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        window=8, global_every=2, ssm_state=8, ssm_head_dim=16,
        ssm_chunk=8,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
