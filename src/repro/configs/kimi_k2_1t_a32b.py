"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 experts top-8 (paper-table
config) [arXiv:2501.kimi2].

Per the assigned table this uses GQA kv=8 (real Kimi K2 uses MLA — recorded
as an assignment-table simplification in DESIGN.md). d_ff=2048 is the
per-expert width. Adafactor + bf16 params so optimizer state fits
512 × 16 GB HBM (DESIGN.md §5).
"""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
        d_ff=2048, vocab_size=163840, head_dim=112,
        num_experts=384, top_k=8,
        norm="rmsnorm", act="silu", tie_embeddings=False,
        optimizer="adafactor", remat="full",
        remat_block=8, microbatches=2, accum_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="kimi-k2-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=32, vocab_size=512,
        num_experts=4, top_k=2,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
