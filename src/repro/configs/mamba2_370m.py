"""mamba2-370m — attention-free SSD (state-space duality)
[arXiv:2405.21060]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        rope_type="none",
        norm="rmsnorm", tie_embeddings=True,
        remat="full",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="mamba2-smoke", num_layers=2, d_model=64, vocab_size=512,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
