"""qwen2-vl-2b — VLM text backbone with M-RoPE; patch-embed frontend is a
STUB (input_specs provides precomputed patch embeddings)
[arXiv:2409.12191; hf]. mrope_sections are half-dim sizes (sum = hd/2)."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        d_ff=8960, vocab_size=151936, head_dim=128,
        qkv_bias=True, rope_type="mrope", mrope_sections=(16, 24, 24),
        rope_theta=1e6,
        norm="rmsnorm", act="silu", tie_embeddings=True,
        frontend="vision_stub", num_patches=256,
        remat="full",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="qwen2-vl-smoke", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        mrope_sections=(2, 3, 3), num_patches=4,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
