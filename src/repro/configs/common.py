"""Shared shape table + config registry.

Every architecture id maps to:
  full()  — the exact assigned configuration (dry-run only; ShapeDtypeStruct)
  smoke() — a reduced same-family config for CPU smoke tests

Shapes (assigned to every LM arch):
  train_4k    : seq 4096,   global batch 256   -> train_step
  prefill_32k : seq 32768,  global batch 32    -> prefill
  decode_32k  : seq 32768,  global batch 128   -> decode_step (1 new token)
  long_500k   : seq 524288, global batch 1     -> decode_step (sub-quadratic
                archs only: mamba2, hymba; full-attention archs skip)
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = [
    "qwen2_0_5b",
    "olmo_1b",
    "minicpm_2b",
    "granite_3_2b",
    "whisper_large_v3",
    "qwen2_vl_2b",
    "hymba_1_5b",
    "mamba2_370m",
    "kimi_k2_1t_a32b",
    "dbrx_132b",
]

# archs able to run the 500k-decode cell (sub-quadratic / windowed+SSM)
LONG_CONTEXT_ARCHS = {"hymba_1_5b", "mamba2_370m"}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke() if smoke else mod.full()


def live_cells():
    """All (arch, shape) dry-run cells after documented skips."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES if shape_applicable(a, s)]
