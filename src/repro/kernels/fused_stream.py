"""Pallas TPU kernels: fused tiles for the streamed source × executor folds.

The streamed folds (``engine.fold_min_d2`` / ``assign_nearest_source`` /
``argmin_dist2_over_source`` and the executors' EIM filter round) are the
hot per-pass loops of the paper's MapReduce rounds: every super-shard is
read once, distance-reduced against a small resident center set, and folded
into O(m)- or O(rank)-sized state. On the reference path each block costs
several XLA dispatches (distances, min, where, top-k) with the ``(rows, m)``
distance block materialized between them. The kernels here fuse one block's
whole share of the round into a single ``pl.pallas_call``: each ``(bn, d)``
row tile is read from HBM exactly once, the MXU computes the
``|x|²+|c|²−2·x·cᵀ`` tile, and the min-reduce / carried d(x,S) update /
per-tile top-k all happen while the tile is VMEM-resident — the
bandwidth-bound one-pass-per-round claim of §3/§5.1, on the out-of-core
path and not just the legacy in-memory one.

Design contract (shared by all the kernels here; tests/test_engine.py pins
it bitwise against the ref oracle in interpret mode):

* **Rows-only tiling.** The grid walks row tiles; the ``(m, d)`` center set
  stays whole in VMEM. Per-row arithmetic is therefore identical to the
  un-tiled reference expression — row-blocking a matmul's major operand
  does not change per-element accumulation order — which is what makes the
  Pallas path bitwise-equal to ref, not merely allclose. VMEM per step is
  ``4·(bn·d + m·d + bn·m)`` bytes plus O(bn) vectors; the caller bounds
  ``bn`` via ``chunk`` (engine._stream_bn).
* **Masked ragged tails.** Callers pad every block to one fixed
  ``rows_p = ceil(rows/bn)·bn`` shape and pass validity as an *operand*
  (f32 0/1 — bool has no native TPU tile layout), so one compilation
  serves every block of a stream, tail included; padded lanes carry the
  ``-3.4e38`` sentinel through the reductions and can never win.
* **First-occurrence arg-semantics.** In-tile arg-reductions use
  ``jnp.argmin``/``argmax`` (first occurrence); cross-tile merges use
  strict ``<``/``>`` so the earliest tile keeps ties — composing to exactly
  ``jnp.argmin``/``argmax`` over the whole stream.
* **Unrolled top-k.** ``lax.top_k``/``sort`` are not relied on inside the
  tile; the per-tile top-``rank`` is ``rank`` unrolled max+argmax
  extractions (rank is a static, O(log n)-sized Select parameter). The
  extracted multiset equals ``lax.top_k``'s, so the caller's
  ``merge_top_k`` fold is bitwise the monolithic top-k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Default row-tile for the streamed kernels: 512 rows keeps the
# (bn·d + m·d + bn·m) f32 working set comfortably under VMEM for the
# d, m regimes the folds see (centers ≲ a few k rows).
DEFAULT_BN = 512

# numpy scalars, NOT jnp: a jnp scalar is a device array, which a Pallas
# kernel body would capture as a constant instead of inlining as a literal.
_BIG = np.float32(3.4e38)
_NEG = np.float32(-3.4e38)


def _dist2_tile(x, c):
    """(bn, d) × (m, d) -> (bn, m) squared distances, the exact expression
    ``ref.pairwise_dist2`` evaluates (clamped MXU decomposition) — the
    bitwise contract of the whole module hangs on this being the same
    per-element arithmetic as the oracle."""
    xn = jnp.sum(x * x, axis=-1, keepdims=True)           # (bn, 1)
    cn = jnp.sum(c * c, axis=-1, keepdims=True)           # (m, 1)
    prod = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # (bn, m)  MXU
    return jnp.maximum(xn + cn.T - 2.0 * prod, 0.0)


def _top_rank(cand, rank: int):
    """Per-tile descending top-``rank`` by unrolled max extraction.

    Each step removes exactly one lane (the first-occurrence argmax), so
    duplicates keep their multiplicity and the value multiset equals
    ``lax.top_k(cand, rank)`` — with ``rank > bn`` the surplus slots fill
    with the ``_NEG`` sentinel, exactly like ``engine.top_k_init``.
    """
    lanes = jax.lax.iota(jnp.int32, cand.shape[0])
    out = []
    for _ in range(rank):
        i = jnp.argmax(cand).astype(jnp.int32)
        out.append(cand[i])
        cand = jnp.where(lanes == i, _NEG, cand)
    return jnp.stack(out)


def _filter_kernel(x_ref, c_ref, ds_ref, hm_ref, newds_ref, top_ref, *,
                   rank: int):
    x = x_ref[...].astype(jnp.float32)                    # (bn, d)
    c = c_ref[...].astype(jnp.float32)                    # (m, d)
    d2 = _dist2_tile(x, c)
    new_ds = jnp.minimum(ds_ref[...], jnp.min(d2, axis=-1))
    newds_ref[...] = new_ds
    # hm gates top-k candidacy only (EIM's H set ∧ tail validity); the
    # d(x,S) update above runs on every lane — callers slice padding off.
    cand = jnp.where(hm_ref[...] > 0, new_ds, _NEG)
    top_ref[...] = _top_rank(cand, rank)[None, :]


@functools.partial(jax.jit, static_argnames=("rank", "bn", "interpret"))
def fused_filter_blocks(
    x: jnp.ndarray,
    c: jnp.ndarray,
    d_s: jnp.ndarray,
    hm: jnp.ndarray,
    *,
    rank: int,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
):
    """EIM Rounds 2–3 block tile: one fused pass computing
    ``new_d_s = min(d_s, d(x, c)²)`` and each tile's descending
    top-``rank`` of ``where(hm > 0, new_d_s, -inf)``.

    ``x (n, d)`` with ``n % bn == 0`` (callers pad), ``d_s (n,)`` f32,
    ``hm (n,)`` f32 0/1. Returns ``(new_d_s (n,), tops (n/bn, rank))``;
    the caller merges tile tops with ``engine.merge_top_k`` (top-k values
    are blocking-invariant). With ``rank=1`` and ``d_s = +BIG`` this is
    the covering-radius fold's block max of min-distances.
    """
    n, d = x.shape
    m = c.shape[0]
    assert n % bn == 0, (n, bn)
    nb = n // bn
    return pl.pallas_call(
        functools.partial(_filter_kernel, rank=rank),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1, rank), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((nb, rank), jnp.float32),
        ],
        interpret=interpret,
    )(x, c, d_s, hm)


def _filter_kernel_w(x_ref, c_ref, ds_ref, hm_ref, w_ref, newds_ref,
                     top_ref, *, rank: int):
    x = x_ref[...].astype(jnp.float32)                    # (bn, d)
    c = c_ref[...].astype(jnp.float32)                    # (m, d)
    d2 = _dist2_tile(x, c)
    new_ds = jnp.minimum(ds_ref[...], jnp.min(d2, axis=-1))
    newds_ref[...] = new_ds
    # Weights join hm in gating candidacy only: a w <= 0 row is absent
    # from the weighted instance, so it cannot contribute to the fold's
    # top-k, but its carried d(x,S) still updates like any padded lane.
    cand = jnp.where((hm_ref[...] > 0) & (w_ref[...] > 0), new_ds, _NEG)
    top_ref[...] = _top_rank(cand, rank)[None, :]


@functools.partial(jax.jit, static_argnames=("rank", "bn", "interpret"))
def fused_filter_blocks_w(
    x: jnp.ndarray,
    c: jnp.ndarray,
    d_s: jnp.ndarray,
    hm: jnp.ndarray,
    w: jnp.ndarray,
    *,
    rank: int,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
):
    """Weighted sibling of ``fused_filter_blocks``: per-row f32 weights
    ``w (n,)`` ride as one extra VMEM operand (``4·bn`` bytes per step on
    top of the plain tile's working set) and gate top-k candidacy — rows
    with ``w <= 0`` are absent from the weighted instance. The arithmetic
    of the d(x,S) update and the top-k extraction is untouched, so with
    ``w > 0`` everywhere (unit weights) the program computes bitwise the
    plain kernel's outputs (pinned in tests/test_engine.py). A separate
    entry point — not a flag on ``fused_filter_blocks`` — so the plain
    kernel's compiled program is byte-identical to before this refactor.
    """
    n, d = x.shape
    m = c.shape[0]
    assert n % bn == 0, (n, bn)
    nb = n // bn
    return pl.pallas_call(
        functools.partial(_filter_kernel_w, rank=rank),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1, rank), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((nb, rank), jnp.float32),
        ],
        interpret=interpret,
    )(x, c, d_s, hm, w)


def _assign_kernel(x_ref, c_ref, idx_ref, d2_ref):
    d2 = _dist2_tile(x_ref[...].astype(jnp.float32),
                     c_ref[...].astype(jnp.float32))
    d2_ref[...] = jnp.min(d2, axis=-1)
    idx_ref[...] = jnp.argmin(d2, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def fused_assign_blocks(
    x: jnp.ndarray,
    c: jnp.ndarray,
    *,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
):
    """Nearest-center tile for the streamed assignment fold: returns
    ``(idx (n,) int32, d2 (n,) f32)``. ``n % bn == 0`` (callers pad and
    slice the tail back off — no mask is needed because padded rows'
    outputs are simply discarded). Unlike ``assign.py`` this keeps the
    center set un-tiled, so in-tile ``argmin`` is the whole first-
    occurrence answer and values are bitwise the ref oracle's.
    """
    n, d = x.shape
    m = c.shape[0]
    assert n % bn == 0, (n, bn)
    idx, d2 = pl.pallas_call(
        _assign_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x, c)
    return idx, d2


def _argmin_rows_kernel(x_ref, c_ref, vm_ref, bestd_ref, besti_ref):
    i = pl.program_id(0)
    bn = x_ref.shape[0]
    d2 = _dist2_tile(x_ref[...].astype(jnp.float32),
                     c_ref[...].astype(jnp.float32))      # (bn, m)
    # Invalid (padded) rows go to the +BIG sentinel so they can never be
    # any center's nearest row (real distances are finite and smaller).
    d2 = jnp.where(vm_ref[...][:, None] > 0, d2, _BIG)
    loc_d = jnp.min(d2, axis=0)                           # (m,)
    loc_i = jnp.argmin(d2, axis=0).astype(jnp.int32) + i * bn

    @pl.when(i == 0)
    def _init():
        bestd_ref[...] = loc_d
        besti_ref[...] = loc_i

    @pl.when(i > 0)
    def _update():
        prev_d = bestd_ref[...]
        # Strict < keeps the earliest tile on ties — composing with the
        # in-tile first-occurrence argmin to global jnp.argmin semantics.
        take = loc_d < prev_d
        bestd_ref[...] = jnp.where(take, loc_d, prev_d)
        besti_ref[...] = jnp.where(take, loc_i, besti_ref[...])


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def fused_argmin_blocks(
    x: jnp.ndarray,
    c: jnp.ndarray,
    vm: jnp.ndarray,
    *,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
):
    """Per-center argmin over a block's rows: for each center row of
    ``c (m, d)``, the (min d², first-occurrence argmin row) over the valid
    rows of ``x (n, d)``. ``vm (n,)`` is the f32 0/1 row-validity mask;
    ``n % bn == 0``. Returns ``(best_d (m,), best_i (m,) int32)`` — the
    running (m,)-carry accumulates across tiles in the revisited output
    block (sequential TPU grid), so the block never materializes (n, m).
    """
    n, d = x.shape
    m = c.shape[0]
    assert n % bn == 0, (n, bn)
    best_d, best_i = pl.pallas_call(
        _argmin_rows_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=interpret,
    )(x, c, vm)
    return best_d, best_i
