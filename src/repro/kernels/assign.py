"""Pallas TPU kernel: fused nearest-center assignment.

Computes ``argmin_j |x_i - c_j|^2`` without materializing the full (n,m)
distance matrix in HBM: the grid walks center tiles in the minor dimension
and keeps a running (min, argmin) pair per point tile in the revisited
output block (TPU grids execute sequentially, so cross-step accumulation
into an output block whose index_map ignores the minor grid axis is the
standard Pallas reduction pattern).

VMEM per step: x (bn,d) + c (bm,d) + two (bn,1) accumulators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 512
DEFAULT_BM = 256


def _assign_kernel(x_ref, c_ref, d2_ref, idx_ref):
    j = pl.program_id(1)
    bm = c_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)                   # (bn, d)
    c = c_ref[...].astype(jnp.float32)                   # (bm, d)
    xn = jnp.sum(x * x, axis=-1, keepdims=True)          # (bn, 1)
    cn = jnp.sum(c * c, axis=-1, keepdims=True)          # (bm, 1)
    prod = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                    # (bn, bm)
    d2 = jnp.maximum(xn + cn.T - 2.0 * prod, 0.0)
    loc_min = jnp.min(d2, axis=-1)                       # (bn,)
    loc_arg = jnp.argmin(d2, axis=-1).astype(jnp.int32) + j * bm

    @pl.when(j == 0)
    def _init():
        d2_ref[...] = loc_min[:, None]
        idx_ref[...] = loc_arg[:, None]

    @pl.when(j > 0)
    def _update():
        prev = d2_ref[...][:, 0]
        take = loc_min < prev
        d2_ref[...] = jnp.where(take, loc_min, prev)[:, None]
        idx_ref[...] = jnp.where(take, loc_arg, idx_ref[...][:, 0])[:, None]


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def assign_nearest_blocks(
    x: jnp.ndarray,
    c: jnp.ndarray,
    *,
    bn: int = DEFAULT_BN,
    bm: int = DEFAULT_BM,
    interpret: bool = False,
):
    """Returns ``(idx (n,1) int32, d2 (n,1) f32)`` nearest-center per point."""
    n, d = x.shape
    m = c.shape[0]
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    grid = (n // bn, m // bm)
    d2, idx = pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x, c)
    return idx, d2
