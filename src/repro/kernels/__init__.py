"""Pallas TPU kernels for the k-center distance hot-spots (+ jnp oracles).

Modules:
  pairwise.py     — tiled pairwise squared-distance matrix (MXU)
  fused_argfar.py — fused Gonzalez step: dist + running-min + arg-farthest
  assign.py       — fused nearest-center assignment (streaming argmin)
  engine.py       — chunked execution engine (impl resolution, padding,
                    row-chunk streaming under a memory budget)
  ops.py          — public API façade over the engine (stable signatures)
  ref.py          — pure-jnp oracles (semantics contract + CPU fast path)
"""
from . import engine, ops, ref  # noqa: F401
