"""Pallas TPU kernels for the k-center distance hot-spots (+ jnp oracles).

Modules:
  pairwise.py     — tiled pairwise squared-distance matrix (MXU)
  fused_argfar.py — fused Gonzalez step: dist + running-min + arg-farthest
  assign.py       — fused nearest-center assignment (streaming argmin)
  ops.py          — public jit wrappers (padding, impl resolution)
  ref.py          — pure-jnp oracles (semantics contract + CPU fast path)
"""
from . import ops, ref  # noqa: F401
