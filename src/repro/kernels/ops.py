"""Public wrappers over the k-center distance engine (API façade).

The execution logic — ``impl`` resolution, shape padding, and row-chunk
streaming under a memory budget — lives in ``repro.kernels.engine``; this
module re-exposes it under the stable historical names so existing callers
(core algorithms, tests, benchmarks) are untouched. The names are direct
aliases, so signatures, defaults, and docstrings have a single home in
engine.py.

``impl`` resolution:
  * ``"auto"``   — Pallas on TPU, reference jnp elsewhere (CPU/GPU).
  * ``"pallas"`` — force the Pallas kernel (interpret mode off-TPU; this is
                   the path tests use to validate kernels on CPU).
  * ``"ref"``    — force the pure-jnp oracle.

New in the chunked engine (all optional, default = legacy behavior):
  * ``chunk``          — max rows of ``x`` processed per streamed step;
  * ``memory_budget``  — bytes; the engine derives ``chunk`` from the
                         working-set model ``4·chunk·(m+d) + 4·m·d``.

The budget bounds *working* memory — the streamed tile plus resident
centers. ``pairwise_dist2`` is the exception: its (n, m) *output* is
inherently O(n·m) and is not covered by the model (chunking there bounds
only the per-step transients); use ``assign_nearest`` /
``fused_min_argmax`` / ``argmin_dist2_over_rows`` when the caller only
needs a reduction of the distance block.

See ``repro/kernels/engine.py`` for the memory model and the jax-version
support notes.
"""
from __future__ import annotations

from . import engine, ref  # noqa: F401  (ops.ref is public API)

resolve_chunk = engine.resolve_chunk
dist2_to_center = engine.dist2_to_center
pairwise_dist2 = engine.pairwise_dist2
fused_min_argmax = engine.fused_min_argmax
assign_nearest = engine.assign_nearest
assign_bucketed = engine.assign_bucketed
argmin_dist2_over_rows = engine.argmin_dist2_over_rows

# Source folds (engine.py): block-streamed ops over a PointSource, so the
# input itself — not just the distance block — stays out of device memory.
resolve_block_rows = engine.resolve_block_rows
fold_min_d2 = engine.fold_min_d2
fold_top_k_min_d2 = engine.fold_top_k_min_d2
assign_nearest_source = engine.assign_nearest_source
argmin_dist2_over_source = engine.argmin_dist2_over_source

# Fused streamed filter primitives (engine.py over kernels/fused_stream.py):
# the executors' EIM Rounds 2–3 block step — d(x,S) min-update + per-block
# top-k in one pass, Pallas tile or jnp oracle per ``impl`` (bitwise-equal).
filter_tile_update = engine.filter_tile_update
eim_filter_block = engine.eim_filter_block

# Counter-based per-row sampling + streamed top-k (engine.py): the
# blocking-invariant Bernoulli draws and the cross-block pivot Select that
# the out-of-core EIM sampler is built on.
uniform_rows = engine.uniform_rows
bernoulli_rows = engine.bernoulli_rows
bernoulli_rows_block = engine.bernoulli_rows_block
split_index_words = engine.split_index_words
uniform_rows_at = engine.uniform_rows_at
bernoulli_rows_at = engine.bernoulli_rows_at
bernoulli_rows_at_block = engine.bernoulli_rows_at_block
top_k_init = engine.top_k_init
merge_top_k = engine.merge_top_k
fold_top_k = engine.fold_top_k
