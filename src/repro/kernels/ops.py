"""Public jit'd wrappers over the k-center kernels.

``impl`` resolution:
  * ``"auto"``   — Pallas on TPU, reference jnp elsewhere (CPU/GPU).
  * ``"pallas"`` — force the Pallas kernel (interpret mode off-TPU; this is
                   the path tests use to validate kernels on CPU).
  * ``"ref"``    — force the pure-jnp oracle.

Wrappers own shape padding: kernels require block-divisible sizes, callers
don't. Padding rows use +inf min-distances / points-at-infinity so they can
never win an argmax/argmin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .assign import DEFAULT_BM as _A_BM
from .assign import DEFAULT_BN as _A_BN
from .assign import assign_nearest_blocks
from .fused_argfar import DEFAULT_BN as _F_BN
from .fused_argfar import fused_min_argmax_blocks
from .pairwise import DEFAULT_BM as _P_BM
from .pairwise import DEFAULT_BN as _P_BN
from .pairwise import pairwise_dist2 as _pairwise_pallas

_BIG = jnp.float32(3.4e38)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str):
    """-> (use_pallas, interpret)"""
    if impl == "auto":
        return (True, False) if _on_tpu() else (False, False)
    if impl == "pallas":
        return True, not _on_tpu()
    if impl == "ref":
        return False, False
    raise ValueError(f"unknown impl {impl!r}")


def _pad_rows(a: jnp.ndarray, mult: int, fill: float):
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a, n
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1), constant_values=fill), n


def dist2_to_center(x, c, *, impl: str = "auto"):
    """Squared distance of each row of x (n,d) to center c (d,)."""
    # Single-center distance is a pure VPU pass; the fused kernel covers the
    # perf-critical use. Reference path is already optimal here.
    del impl
    return ref.dist2_to_center(x, c)


def pairwise_dist2(x, c, *, impl: str = "auto", bn: int = _P_BN, bm: int = _P_BM):
    """(n,d),(m,d) -> (n,m) squared Euclidean distances."""
    use_pallas, interpret = _resolve(impl)
    if not use_pallas:
        return ref.pairwise_dist2(x, c)
    n, m = x.shape[0], c.shape[0]
    bn_, bm_ = min(bn, max(8, n)), min(bm, max(8, m))
    xp, n0 = _pad_rows(x, bn_, 0.0)
    cp, m0 = _pad_rows(c, bm_, 0.0)
    out = _pairwise_pallas(xp, cp, bn=bn_, bm=bm_, interpret=interpret)
    return out[:n0, :m0]


def fused_min_argmax(x, c, min_d2, *, impl: str = "auto", bn: int = _F_BN):
    """Fused Gonzalez step: (new_min_d2 (n,), far_val (), far_idx () i32)."""
    use_pallas, interpret = _resolve(impl)
    if not use_pallas:
        return ref.fused_min_argmax(x, c, min_d2)
    n = x.shape[0]
    bn_ = min(bn, max(8, n))
    xp, _ = _pad_rows(x, bn_, 0.0)
    # Padded rows get -inf min-dist so they never become the farthest point
    # and their updated min stays -inf.
    mdp, _ = _pad_rows(min_d2, bn_, -_BIG)
    new_md, bmax, barg = fused_min_argmax_blocks(xp, c, mdp, bn=bn_, interpret=interpret)
    blk = jnp.argmax(bmax[:, 0])
    return new_md[:n], bmax[blk, 0], barg[blk, 0]


def assign_nearest(x, c, *, impl: str = "auto", bn: int = _A_BN, bm: int = _A_BM):
    """Nearest-center assignment: (idx (n,) i32, d2 (n,))."""
    use_pallas, interpret = _resolve(impl)
    if not use_pallas:
        return ref.assign_nearest(x, c)
    n, m = x.shape[0], c.shape[0]
    bn_, bm_ = min(bn, max(8, n)), min(bm, max(8, m))
    xp, _ = _pad_rows(x, bn_, 0.0)
    # Pad centers at +inf-ish distance: fill with a huge coordinate so padded
    # centers are never nearest.
    cp, _ = _pad_rows(c, bm_, 1e18)
    idx, d2 = assign_nearest_blocks(xp, cp, bn=bn_, bm=bm_, interpret=interpret)
    return idx[:n, 0], d2[:n, 0]
