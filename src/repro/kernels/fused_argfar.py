"""Pallas TPU kernel: fused Gonzalez iteration (dist + min + arg-farthest).

Each Gonzalez step does three O(n) passes in the naive formulation:
  1. d2  = |x - c_new|^2          (distance to the newly chosen center)
  2. md  = min(md, d2)            (running min-distance update)
  3. far = argmax(md)             (next center = farthest point)

Fusing them keeps each ``(bn,d)`` point tile resident in VMEM for exactly
one HBM read (plus the (bn,) min-dist vector read/write), turning the step
from 3 HBM sweeps into ~1 — the memory-roofline win the paper's runtime
analysis (§5.1, "low constant in the O(kn/m)") corresponds to on TPU.

Grid: ``(n/bn,)``. Per-block outputs: updated min-dist tile, plus the
block-local (max value, global argmax index) pair written to a
``(nblocks, 1)`` pair of arrays; the final cross-block argmax reduction is
O(n/bn) and runs in the jit'd wrapper (ops.fused_min_argmax).

Layout note: the per-block scalar outputs are kept as (1,1) f32/i32 tiles
(2-D, so they map onto TPU vector layouts); on real hardware a SMEM
scalar output would also work, interpret mode validates either.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 1024


def _fused_kernel(x_ref, c_ref, md_ref, newmd_ref, bmax_ref, barg_ref):
    pid = pl.program_id(0)
    bn = x_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)               # (bn, d)
    c = c_ref[...].astype(jnp.float32)               # (1, d)
    diff = x - c                                     # broadcast over rows
    d2 = jnp.sum(diff * diff, axis=-1)               # (bn,)  VPU
    new_md = jnp.minimum(md_ref[...], d2)            # (bn,)
    newmd_ref[...] = new_md
    loc = jnp.argmax(new_md).astype(jnp.int32)
    bmax_ref[0, 0] = new_md[loc]
    barg_ref[0, 0] = loc + pid * bn


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def fused_min_argmax_blocks(
    x: jnp.ndarray,
    c: jnp.ndarray,
    min_d2: jnp.ndarray,
    *,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
):
    """Returns ``(new_min_d2 (n,), block_max (nb,1), block_arg (nb,1))``.

    ``n`` must divide ``bn`` (ops.py pads). The caller reduces the block
    maxima to the global farthest point.
    """
    n, d = x.shape
    assert n % bn == 0, (n, bn)
    nb = n // bn
    return pl.pallas_call(
        _fused_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x, c.reshape(1, -1), min_d2)
