"""Pure-jnp reference oracles for the k-center distance kernels.

These are the semantics contracts: every Pallas kernel in this package is
validated (shape/dtype sweeps, interpret mode) against these functions.
They are also the production path on non-TPU backends.

All distances are *squared* Euclidean (monotone in the Euclidean metric, so
center selection / assignment / argmax-farthest are identical; callers take
a sqrt only when reporting radii).
"""
from __future__ import annotations

import jax.numpy as jnp


def dist2_to_center(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared distances from every row of ``x (n,d)`` to one center ``c (d,)``."""
    diff = x - c[None, :]
    return jnp.sum(diff * diff, axis=-1)


def pairwise_dist2(x: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared distances ``(n,m)`` between rows of ``x (n,d)`` and ``c (m,d)``.

    Uses the matmul (MXU) decomposition ``|x|^2 - 2 x.c^T + |c|^2`` with a
    clamp at zero (the decomposition can go slightly negative in floating
    point).
    """
    xn = jnp.sum(x * x, axis=-1, keepdims=True)            # (n,1)
    cn = jnp.sum(c * c, axis=-1, keepdims=True).T          # (1,m)
    d2 = xn + cn - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    return jnp.maximum(d2, 0.0)


def fused_min_argmax(x: jnp.ndarray, c: jnp.ndarray, min_d2: jnp.ndarray):
    """One Gonzalez iteration's hot path, fused.

    Given the new center ``c``, update the running min-squared-distance
    ``min_d2 (n,)`` and return the farthest point under the updated
    distances.

    Returns ``(new_min_d2 (n,), far_val (), far_idx () int32)``.
    """
    d2 = dist2_to_center(x, c)
    new_min = jnp.minimum(min_d2, d2)
    idx = jnp.argmax(new_min).astype(jnp.int32)
    return new_min, new_min[idx], idx


def assign_nearest(x: jnp.ndarray, c: jnp.ndarray):
    """Nearest-center assignment.

    Returns ``(idx (n,) int32, d2 (n,))`` — per-point nearest center index
    and its squared distance.
    """
    d2 = pairwise_dist2(x, c)
    idx = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    return idx, jnp.min(d2, axis=-1)
