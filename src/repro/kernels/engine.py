"""Chunked distance-engine for the k-center kernels.

This module is the real execution layer behind ``repro.kernels.ops`` (which
is kept as a thin façade for API stability). It owns:

  * ``impl`` resolution — Pallas on TPU, pure-jnp reference elsewhere;
  * shape padding — kernels need block-divisible sizes, callers don't;
  * **row-chunk streaming** — the paper-motivated memory model below.

Memory model (paper §3.3 capacity argument / Ceccarello et al. 1802.09205):
the un-chunked formulation of ``assign_nearest`` / ``pairwise_dist2``
materializes an ``(n, m)`` distance block, i.e. O(n·m) working memory — fine
when the shard fits, fatal when n exceeds device memory. With a ``chunk``
parameter every op streams row-blocks of at most ``chunk`` points:

  * reference path — a ``lax.scan`` over ``(chunk, d)`` tiles, so peak
    working memory is O(chunk·(m + d) + m·d) regardless of n;
  * Pallas path — ``chunk`` caps the row block size ``bn`` fed to the grid
    (TPU grids already execute tiles sequentially, so the grid *is* the
    stream; ``chunk`` bounds the per-step VMEM footprint).

``chunk=None`` (default) preserves the legacy un-chunked behavior exactly.
``memory_budget`` (bytes) derives a chunk from the working-set model
``4·chunk·(m + d) + 4·m·d <= budget``. Results are independent of ``chunk``
(parity-tested in tests/test_engine.py): elementwise minima are bitwise
identical, and cross-chunk arg-reductions resolve ties to the first
occurrence exactly like ``jnp.argmax``/``argmin``.

jax version support: this module is pure jnp/lax/pallas and runs unchanged
on jax 0.4.x and 0.6+ (the version-sensitive mesh/shard_map surface lives
in ``repro.compat``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import fused_stream
from . import ref
from .assign import DEFAULT_BM as _A_BM
from .assign import DEFAULT_BN as _A_BN
from .assign import assign_nearest_blocks
from .fused_argfar import DEFAULT_BN as _F_BN
from .fused_argfar import fused_min_argmax_blocks
from .pairwise import DEFAULT_BM as _P_BM
from .pairwise import DEFAULT_BN as _P_BN
from .pairwise import pairwise_dist2 as _pairwise_pallas

# np scalars, not jnp: module import must not commit the jax backend
# (jax.distributed.initialize refuses to run after any computation).
_BIG = np.float32(3.4e38)
_NEG = np.float32(-3.4e38)


# ---------------------------------------------------------------------------
# impl / chunk resolution
# ---------------------------------------------------------------------------

def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.lru_cache(maxsize=None)
def _pallas_native() -> bool:
    """Can ``pl.pallas_call`` lower *natively* on the default backend?

    TPU always lowers (Mosaic). On GPU the Triton lowering exists only on
    CUDA jaxlibs of sufficient vintage — keying on the backend *name*
    alone (the old ``_on_tpu`` test) both under-enables (GPU never got the
    kernels) and would over-enable (ROCm / old jaxlibs raise at lowering
    time) — so GPU is feature-detected by compiling one trivial kernel.
    Anything else (CPU) has no native lowering; interpret mode remains
    available via ``impl="pallas"``. Cached per process — backend choice
    is fixed at jax init.
    """
    backend = jax.default_backend()
    if backend == "tpu":
        return True
    if backend == "gpu":
        try:
            from jax.experimental import pallas as pl

            def _probe(x_ref, o_ref):
                o_ref[...] = x_ref[...] + 1.0

            out = pl.pallas_call(
                _probe,
                out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            )(jnp.zeros((8, 128), jnp.float32))
            jax.block_until_ready(out)
            return True
        except Exception:
            return False
    return False


def _resolve(impl: str):
    """-> (use_pallas, interpret)

    ``auto`` uses the Pallas kernels wherever they lower natively (TPU
    Mosaic, feature-detected GPU Triton) and the jnp reference elsewhere —
    never interpret mode, which is a correctness tool, not a fast path.
    ``pallas`` forces the kernels, gracefully falling back to interpret
    mode on backends without a native lowering (the form the CPU CI parity
    tests exercise). ``ref`` forces the oracle.
    """
    if impl == "auto":
        return (True, False) if _pallas_native() else (False, False)
    if impl == "pallas":
        return True, not _pallas_native()
    if impl == "ref":
        return False, False
    raise ValueError(f"unknown impl {impl!r}")


def resolve_chunk(n: int, m: int, d: int, *, chunk: int | None = None,
                  memory_budget: int | None = None,
                  sublane: int | None = None) -> int | None:
    """Row-chunk size for an ``(n, d) × (m, d)`` distance op.

    Explicit ``chunk`` wins (clipped to ``[1, n]``; ``chunk >= n`` means one
    chunk, i.e. the un-chunked compute with chunked code path). Otherwise a
    ``memory_budget`` in bytes is solved against the f32 working-set model
    ``4·chunk·(m + d) + 4·m·d`` — the streamed tile plus resident centers.
    Returns None when neither is given (legacy un-chunked path).

    ``sublane`` (Pallas callers pass 8, the f32 sublane minimum) keeps a
    *budget-derived* chunk honest against the kernels' block rounding: the
    solved rows are floored to a sublane multiple — never rounded up past
    what the budget covers — and a budget that cannot hold even one
    ``sublane``-row block raises instead of silently overshooting.
    Explicit ``chunk`` is a shape request, not a budget, and is returned
    unrounded (``_pallas_bn`` may round it up).
    """
    if chunk is not None:
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        return min(int(chunk), max(n, 1))
    if memory_budget is not None:
        avail = memory_budget - 4 * m * d
        rows = avail // (4 * (m + d)) if avail > 0 else 0
        if sublane is not None and sublane > 1:
            # Floor to the sublane multiple the kernel will actually run:
            # rounding *up* here could exceed the stated budget (rows is
            # the largest count the model covers).
            rows = (rows // sublane) * sublane
            if rows < 1:
                raise ValueError(
                    f"memory_budget={memory_budget} cannot hold one "
                    f"{sublane}-row sublane block "
                    f"({4 * m * d} bytes of centers + "
                    f"{4 * sublane * (m + d)} bytes/block)")
        if rows < 1:
            raise ValueError(
                f"memory_budget={memory_budget} cannot hold even one row "
                f"(centers alone need {4 * m * d} bytes + {4 * (m + d)}/row)")
        return min(int(rows), max(n, 1))
    return None


def _pad_rows(a: jnp.ndarray, mult: int, fill: float):
    n = a.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return a, n
    return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1), constant_values=fill), n


def _blocks(a: jnp.ndarray, chunk: int, fill: float):
    """Pad rows to a chunk multiple and reshape to (nb, chunk, ...)."""
    ap, n = _pad_rows(a, chunk, fill)
    nb = ap.shape[0] // chunk
    return ap.reshape((nb, chunk) + ap.shape[1:]), n


def _pallas_bn(bn: int, n: int, chunk: int | None) -> int:
    """Row block for the Pallas grid: ≤ bn, ≤ chunk (rounded up to the 8-row
    sublane minimum), never below 8.

    The round-*up* is only safe because budget-derived chunks arrive
    pre-floored to a sublane multiple (``resolve_chunk(..., sublane=8)``),
    so it can engage only for explicit user chunks — a shape request, not
    a byte budget (tests/test_engine.py pins the budget-honesty side).
    """
    bn_ = min(bn, max(8, n))
    if chunk is not None:
        bn_ = min(bn_, max(8, -(-chunk // 8) * 8))
    return bn_


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def dist2_to_center(x, c, *, impl: str = "auto", chunk: int | None = None,
                    memory_budget: int | None = None):
    """Squared distance of each row of x (n,d) to center c (d,)."""
    # Single-center distance has an O(n·d) working set already — no (n,m)
    # block exists to chunk away; the reference pass is optimal everywhere.
    del impl, chunk, memory_budget
    return ref.dist2_to_center(x, c)


def pairwise_dist2(x, c, *, impl: str = "auto", chunk: int | None = None,
                   memory_budget: int | None = None,
                   bn: int = _P_BN, bm: int = _P_BM):
    """(n,d),(m,d) -> (n,m) squared Euclidean distances.

    Note the *output* is inherently O(n·m); chunking bounds the transient
    working set (useful when the caller immediately reduces each row-block,
    and on backends where the fused matmul intermediate is the peak).
    """
    n, m = x.shape[0], c.shape[0]
    d = x.shape[1]
    use_pallas, interpret = _resolve(impl)
    chunk = resolve_chunk(n, m, d, chunk=chunk, memory_budget=memory_budget,
                          sublane=8 if use_pallas else None)
    if use_pallas:
        bn_ = _pallas_bn(bn, n, chunk)
        bm_ = min(bm, max(8, m))
        xp, n0 = _pad_rows(x, bn_, 0.0)
        cp, m0 = _pad_rows(c, bm_, 0.0)
        out = _pairwise_pallas(xp, cp, bn=bn_, bm=bm_, interpret=interpret)
        return out[:n0, :m0]
    if chunk is None or chunk >= n:
        return ref.pairwise_dist2(x, c)
    xb, n0 = _blocks(x, chunk, 0.0)

    def step(_, xrow):
        return None, ref.pairwise_dist2(xrow, c)

    _, d2 = jax.lax.scan(step, None, xb)
    return d2.reshape(-1, m)[:n0]


def fused_min_argmax(x, c, min_d2, *, impl: str = "auto",
                     chunk: int | None = None,
                     memory_budget: int | None = None, bn: int = _F_BN):
    """Fused Gonzalez step: (new_min_d2 (n,), far_val (), far_idx () i32)."""
    n, d = x.shape
    use_pallas, interpret = _resolve(impl)
    chunk = resolve_chunk(n, 1, d, chunk=chunk, memory_budget=memory_budget,
                          sublane=8 if use_pallas else None)
    if use_pallas:
        bn_ = _pallas_bn(bn, n, chunk)
        xp, _ = _pad_rows(x, bn_, 0.0)
        # Padded rows get -inf min-dist so they never become the farthest
        # point and their updated min stays -inf.
        mdp, _ = _pad_rows(min_d2, bn_, -_BIG)
        new_md, bmax, barg = fused_min_argmax_blocks(xp, c, mdp, bn=bn_,
                                                     interpret=interpret)
        blk = jnp.argmax(bmax[:, 0])
        return new_md[:n], bmax[blk, 0], barg[blk, 0]
    if chunk is None or chunk >= n:
        return ref.fused_min_argmax(x, c, min_d2)
    xb, n0 = _blocks(x, chunk, 0.0)
    mdb, _ = _blocks(min_d2, chunk, -_BIG)
    offs = jnp.arange(xb.shape[0], dtype=jnp.int32) * chunk

    def step(carry, inp):
        best_v, best_i = carry
        xrow, mdrow, off = inp
        new_md, v, i = ref.fused_min_argmax(xrow, c, mdrow)
        # Strict > keeps the earliest block on ties — matches the global
        # first-occurrence semantics of jnp.argmax.
        take = v > best_v
        carry = (jnp.where(take, v, best_v),
                 jnp.where(take, i + off, best_i))
        return carry, new_md

    (far_v, far_i), new_md = jax.lax.scan(
        step, (-_BIG, jnp.int32(0)), (xb, mdb, offs))
    return new_md.reshape(-1)[:n0], far_v, far_i


def assign_nearest(x, c, *, impl: str = "auto", chunk: int | None = None,
                   memory_budget: int | None = None,
                   bn: int = _A_BN, bm: int = _A_BM):
    """Nearest-center assignment: (idx (n,) i32, d2 (n,)).

    With ``chunk``/``memory_budget`` the (n, m) distance block never
    materializes — each scan step reduces its (chunk, m) tile to a
    (chunk,) min/argmin pair, so n is bounded by HBM for the *points*
    only, not the distance matrix.
    """
    n, m = x.shape[0], c.shape[0]
    d = x.shape[1]
    use_pallas, interpret = _resolve(impl)
    chunk = resolve_chunk(n, m, d, chunk=chunk, memory_budget=memory_budget,
                          sublane=8 if use_pallas else None)
    if use_pallas:
        bn_ = _pallas_bn(bn, n, chunk)
        bm_ = min(bm, max(8, m))
        xp, _ = _pad_rows(x, bn_, 0.0)
        # Pad centers at +inf-ish distance: fill with a huge coordinate so
        # padded centers are never nearest.
        cp, _ = _pad_rows(c, bm_, 1e18)
        idx, d2 = assign_nearest_blocks(xp, cp, bn=bn_, bm=bm_,
                                        interpret=interpret)
        return idx[:n, 0], d2[:n, 0]
    if chunk is None or chunk >= n:
        return ref.assign_nearest(x, c)
    xb, n0 = _blocks(x, chunk, 0.0)

    def step(_, xrow):
        return None, ref.assign_nearest(xrow, c)

    _, (idx, d2) = jax.lax.scan(step, None, xb)
    return idx.reshape(-1)[:n0], d2.reshape(-1)[:n0]


# Coordinate-space far sentinel for padded/invalid center rows: distance to
# a 1e18-coordinate row is ~1e36·d (or +inf past f32 range) — it loses every
# nearest reduction, so sentinel rows never win an assignment.
_FAR_CENTER = np.float32(1e18)


def assign_bucketed(q, c, cmask, *, impl: str = "auto",
                    chunk: int | None = None):
    """Nearest-center assignment against a *bucketed* cached center set —
    the online-serving query program (``repro/serve/kcenter.py``).

    ``c (m_cap, d)`` is a fixed power-of-two bucket holding ``m <= m_cap``
    live centers and ``cmask (m_cap,)`` marks the live rows (0/1 operand,
    f32 or bool). Invalid rows are pushed to the far coordinate sentinel —
    the same 1e18 fill ``assign_nearest`` pads centers with — so they can
    never win a nearest reduction: for every valid query row the result is
    **bitwise** equal to ``assign_nearest(q[:b], c[:m])``. Callers pad the
    query block to a fixed row bucket and slice the tail off themselves
    (tests/test_serve_kcenter.py pins both contracts).

    Deliberately NOT module-jitted: the repo-wide assignment contract is
    the *eager* ``assign_nearest`` bits, and jitting fuses the
    ``|x|² − 2x·c + |c|²`` matmul differently on CPU (1-ulp d2 drift — the
    same reason ``Executor.radius2`` stays an eager fold). Recompile
    avoidance comes from the fixed bucket shapes instead: every operand
    signature is one of O(log max_batch · log m_cap) buckets, so the op
    cache serves the steady state with zero new compilations. Epoch bumps
    of the serving cache re-upload the *same* shapes — never a new
    signature. reprolint R004 lists this entry point in ``JITTED_CALLEES``
    so a ragged block stream must do the pad dance before reaching it.

    Eager-only for a second reason: the mask is read *concretely* to
    special-case a single live center (the m=1 dot lowers as a matvec with
    different accumulation than the m>=2 gemm — masking it inside the
    bucket would cost 1 ulp of parity), so ``cmask`` must not be a tracer.
    """
    cmask_h = np.asarray(cmask) > 0
    if cmask_h.shape[0] != c.shape[0]:
        raise ValueError(
            f"cmask rows {cmask_h.shape[0]} != center bucket rows {c.shape[0]}")
    nvalid = int(cmask_h.sum())
    if nvalid == 1:
        # XLA lowers the m=1 distance dot as a matvec whose accumulation
        # differs from the m>=2 gemm by 1 ulp, so a single live center
        # masked inside the bucket would break bitwise parity with the
        # unbucketed reference. Route through the true 1-row set — still a
        # fixed operand signature per query bucket — and restore the
        # bucket-row index.
        j = int(np.argmax(cmask_h))
        idx, d2 = assign_nearest(q, jnp.asarray(c)[j:j + 1],
                                 impl=impl, chunk=chunk)
        return idx + jnp.int32(j), d2
    c = jnp.where(jnp.asarray(cmask)[:, None] > 0, c, _FAR_CENTER)
    return assign_nearest(q, c, impl=impl, chunk=chunk)


def argmin_dist2_over_rows(x, c, *, impl: str = "auto",
                           chunk: int | None = None,
                           memory_budget: int | None = None):
    """For each center row of ``c (m,d)``: index of the nearest row of
    ``x (n,d)`` — ``argmin_i |x_i - c_j|^2 -> (m,) i32``.

    Semantically ``assign_nearest(c, x)[0]``, but chunked over the *x*
    rows: the scan keeps an (m,)-sized running (min, argmin) carry, so the
    working set is O(chunk·m) instead of the (m, n) block that formulation
    materializes on the ref path. (The Pallas grid already tiles the n
    axis, so that path delegates to the kernel unchanged.)
    """
    n, d = x.shape
    m = c.shape[0]
    use_pallas, _ = _resolve(impl)
    chunk = resolve_chunk(n, m, d, chunk=chunk, memory_budget=memory_budget,
                          sublane=8 if use_pallas else None)
    if use_pallas or chunk is None or chunk >= n:
        idx, _ = assign_nearest(c, x, impl=impl)
        return idx
    # Pad rows at a far-away coordinate so padding can never be nearest
    # (its distance is ~1e36·d, or +inf past f32 range — both lose).
    xb, _ = _blocks(x, chunk, 1e18)
    offs = jnp.arange(xb.shape[0], dtype=jnp.int32) * chunk

    def step(carry, inp):
        best_d, best_i = carry
        xrow, off = inp
        d2 = ref.pairwise_dist2(xrow, c)                     # (chunk, m)
        loc_d = jnp.min(d2, axis=0)                          # (m,)
        loc_i = jnp.argmin(d2, axis=0).astype(jnp.int32) + off
        # Strict < keeps the earliest row on ties — matches the global
        # first-occurrence semantics of jnp.argmin.
        take = loc_d < best_d
        return (jnp.where(take, loc_d, best_d),
                jnp.where(take, loc_i, best_i)), None

    init = (jnp.full((m,), _BIG), jnp.zeros((m,), jnp.int32))
    (_, idx), _ = jax.lax.scan(step, init, (xb, offs))
    return idx


# ---------------------------------------------------------------------------
# counter-based per-row sampling — Philox-4x32-10 keyed by absolute row index
#
# EIM's Round-1 Bernoulli draws must be *blocking-invariant*: the streamed
# out-of-core path sees the input in super-shards, and the decision for
# global row i may not depend on which shard i landed in (the same trick
# ``SyntheticSource("unif")`` uses with numpy's Philox counter advance).
# ``jax.random.bernoulli`` can't give that — its counters are positions in
# one fixed-shape draw — so this is a counter-based generator whose only
# inputs are (key, absolute row index). Pure uint32 jnp (16-bit limb
# multiplies, no uint64), so it runs identically with JAX_ENABLE_X64 off,
# on any backend, traced or eager — the device fast path and the host-
# driven stream produce bitwise-identical samples.
# ---------------------------------------------------------------------------

_PHILOX_M0 = np.uint32(0xD2511F53)
_PHILOX_M1 = np.uint32(0xCD9E8D57)
_PHILOX_W0 = np.uint32(0x9E3779B9)
_PHILOX_W1 = np.uint32(0xBB67AE85)


def _mulhilo32(a, b):
    """Full 32x32 -> 64 multiply as (hi, lo) uint32 words, via 16-bit limbs
    (jnp uint64 needs x64 mode; uint32 arithmetic wraps mod 2^32)."""
    a_lo, a_hi = a & 0xFFFF, a >> 16
    b_lo, b_hi = b & 0xFFFF, b >> 16
    lo = a * b
    t = a_hi * b_lo + ((a_lo * b_lo) >> 16)        # < 2^32, no wrap
    u = (t & 0xFFFF) + a_lo * b_hi                 # < 2^32, no wrap
    hi = a_hi * b_hi + (t >> 16) + (u >> 16)
    return hi, lo


def _philox_rows(k0, k1, c0, c1):
    """One Philox-4x32-10 output word per counter (c0 = row lo, c1 = row hi)."""
    x0, x1 = c0, c1
    x2 = jnp.zeros_like(c0)
    x3 = jnp.zeros_like(c0)
    for _ in range(10):
        hi0, lo0 = _mulhilo32(_PHILOX_M0, x0)
        hi1, lo1 = _mulhilo32(_PHILOX_M1, x2)
        x0, x1, x2, x3 = hi1 ^ x1 ^ k0, lo1, hi0 ^ x3 ^ k1, lo0
        k0 = k0 + _PHILOX_W0
        k1 = k1 + _PHILOX_W1
    return x0


def _key_words(key):
    """Two uint32 key words from a jax PRNG key (legacy or typed) or a raw
    (2,) uint32 array."""
    key = jnp.asarray(key) if not isinstance(key, jnp.ndarray) else key
    if key.dtype != jnp.uint32:
        key = jax.random.key_data(key)
    key = key.reshape(-1)
    return key[0], key[1]


def _uniform_rows_words(k0, k1, lo, hi, rows: int) -> jnp.ndarray:
    """``uniform_rows`` with the 64-bit start pre-split into uint32 words
    (``lo``/``hi`` may be traced — jit callers pass them as operands so one
    compilation serves every block offset)."""
    c0 = lo + jnp.arange(rows, dtype=jnp.uint32)
    carry = (c0 < lo).astype(jnp.uint32)
    c1 = hi + carry
    bits = _philox_rows(k0, k1, c0, c1)
    # 24 high-entropy bits -> f32 in [0, 1): exact scale, matches the
    # resolution jax.random.uniform uses for f32.
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 ** -24)


def uniform_rows(key, start: int, rows: int) -> jnp.ndarray:
    """Counter-based U[0,1) for absolute rows ``[start, start + rows)``.

    Row i's value depends only on ``(key, i)`` — never on ``start``'s
    blocking — so concatenating per-block calls over any partition of
    ``[0, n)`` is bitwise identical to one full-range call. ``start`` is a
    host int (the 64-bit row index is split into uint32 counter words with
    an explicit carry, so blocks may cross the 2^32 row boundary).
    """
    if rows < 0:
        raise ValueError(f"rows must be >= 0, got {rows}")
    k0, k1 = _key_words(key)
    return _uniform_rows_words(k0, k1, jnp.uint32(start & 0xFFFFFFFF),
                               jnp.uint32((start >> 32) & 0xFFFFFFFF), rows)


def bernoulli_rows(key, start: int, rows: int, p) -> jnp.ndarray:
    """Per-global-row Bernoulli(p) draws for rows ``[start, start + rows)``
    — ``uniform_rows(key, start, rows) < p`` in f32, so callers on the
    device fast path and the streamed path agree bitwise as long as they
    feed the same f32 ``p``."""
    return uniform_rows(key, start, rows) < jnp.asarray(p, jnp.float32)


@functools.partial(jax.jit, static_argnames=("rows",))
def bernoulli_rows_block(key, start_lo, start_hi, rows: int, p):
    """Jitted ``bernoulli_rows`` for host-driven block loops: the 64-bit
    block start arrives pre-split into two uint32 *operands* (``start_lo``,
    ``start_hi``), so one compilation serves every block offset — the form
    the streamed EIM's per-iteration mask generation uses."""
    k0, k1 = _key_words(key)
    u = _uniform_rows_words(k0, k1, start_lo, start_hi, rows)
    return u < jnp.asarray(p, jnp.float32)


def split_index_words(indices) -> tuple[np.ndarray, np.ndarray]:
    """Host-side split of 64-bit absolute row indices into uint32 counter
    words (jnp cannot hold int64 with JAX_ENABLE_X64 off — the split
    happens in numpy before anything touches the device). The words are
    the operand form ``bernoulli_rows_at_block`` consumes."""
    idx = np.asarray(indices, np.uint64).reshape(-1)
    return ((idx & np.uint64(0xFFFFFFFF)).astype(np.uint32),
            (idx >> np.uint64(32)).astype(np.uint32))


def _uniform_at_words(k0, k1, c_lo, c_hi) -> jnp.ndarray:
    bits = _philox_rows(k0, k1, c_lo, c_hi)
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 ** -24)


def uniform_rows_at(key, indices) -> jnp.ndarray:
    """Gather-form ``uniform_rows``: counter-based U[0,1) at *arbitrary*
    absolute row indices.

    Row i's draw is the same pure function of ``(key, i)`` as
    ``uniform_rows`` evaluates, so for any index array ``idx``::

        uniform_rows_at(key, idx) == uniform_rows(key, 0, n)[idx]

    bitwise. This is what keeps the compacted-R streamed EIM's Round-1
    sampling identical to the full-view path: a survivor's Bernoulli
    decision is keyed by its *original* global row index, never by its
    position inside the compacted view. ``indices`` is host numpy
    (64-bit indices are split into uint32 counter words on the host, so
    the call is x64-off safe).
    """
    k0, k1 = _key_words(key)
    lo, hi = split_index_words(indices)
    return _uniform_at_words(k0, k1, jnp.asarray(lo), jnp.asarray(hi))


def bernoulli_rows_at(key, indices, p) -> jnp.ndarray:
    """Per-row Bernoulli(p) draws at arbitrary absolute row indices —
    ``uniform_rows_at(key, indices) < p`` in f32, bitwise identical to
    ``bernoulli_rows(key, 0, n, p)[indices]`` for the same f32 ``p``."""
    return uniform_rows_at(key, indices) < jnp.asarray(p, jnp.float32)


@jax.jit
def bernoulli_rows_at_block(key, idx_lo, idx_hi, p):
    """Jitted gather-form Bernoulli block: the index words arrive as
    *operands* (uint32 arrays of one fixed block shape — callers pad the
    tail), so one compilation per block shape serves every iteration and
    every compacted view."""
    k0, k1 = _key_words(key)
    return _uniform_at_words(k0, k1, idx_lo, idx_hi) < jnp.asarray(
        p, jnp.float32)


# ---------------------------------------------------------------------------
# streamed top-k merge — EIM's Round-2 Select pivot as a cross-block fold
# ---------------------------------------------------------------------------

def top_k_init(k: int) -> jnp.ndarray:
    """Identity carry for ``merge_top_k``: k slots at the -inf sentinel."""
    return jnp.full((k,), _NEG)


def merge_top_k(carry: jnp.ndarray, vals: jnp.ndarray, k: int) -> jnp.ndarray:
    """Fold step: merge a block's values into a running descending top-k.

    Top-k *values* of a multiset are blocking-invariant (unlike arg-
    reductions, no tie-break subtlety), so folding per-block top-k's equals
    the monolithic ``lax.top_k`` over the concatenation bitwise.
    """
    return jax.lax.top_k(jnp.concatenate([carry, vals.reshape(-1)]), k)[0]


def fold_top_k(value_blocks, k: int) -> jnp.ndarray:
    """Top-k values over an iterable of value blocks (descending, padded
    with the -inf sentinel when fewer than k values exist)."""
    top = top_k_init(k)
    for v in value_blocks:
        top = merge_top_k(top, jnp.asarray(v), k)
    return top


# ---------------------------------------------------------------------------
# source folds — streamed ops over a PointSource
#
# A "source" here is duck-typed: anything with ``n``, ``d`` and
# ``blocks(block_rows)`` yielding (<= block_rows, d) float32 device arrays
# covering the rows in order (see repro/data/source.py). These folds are the
# shared entry points the executors (repro/core/executor.py) and the
# source-aware algorithm layer build on: at most ``1 + prefetch``
# super-shards of the input (the consumed block plus the device-side
# prefetch ring) are ever device-resident, so n is bounded by host RAM /
# disk, not HBM.
#
# Two nested capacity knobs exist by design: ``block_rows``/``memory_budget``
# bounds the resident *input block* (this layer), while ``chunk`` bounds the
# per-pass *distance working set* within a block (the layer above). They
# mirror the paper's machine capacity c and its per-round working memory.
# ---------------------------------------------------------------------------

DEFAULT_BLOCK_ROWS = 1 << 16
# Default lookahead depth of the sources' device-side prefetch ring (the
# single home of the constant — repro/data/source.py imports it); at the
# peak 1 + DEFAULT_PREFETCH blocks are device-resident.
DEFAULT_PREFETCH = 2


def resolve_block_rows(n: int, d: int, *, block_rows: int | None = None,
                       memory_budget: int | None = None,
                       default: int = DEFAULT_BLOCK_ROWS,
                       prefetch: int = DEFAULT_PREFETCH) -> int:
    """Super-shard size for streaming an ``(n, d)`` source.

    Explicit ``block_rows`` wins (clipped to ``[1, n]``). Otherwise a
    ``memory_budget`` in bytes is solved against the f32 residency model
    ``(1 + prefetch) · 4·rows·(d + 1)`` — the consumed block plus up to
    ``prefetch`` in-flight blocks coexist under the sources' device-side
    prefetch ring, each with one per-row reduction carry. Falls back to
    ``DEFAULT_BLOCK_ROWS``.
    """
    if block_rows is not None:
        if block_rows < 1:
            raise ValueError(f"block_rows must be >= 1, got {block_rows}")
        return min(int(block_rows), max(n, 1))
    if prefetch < 1:
        raise ValueError(f"prefetch must be >= 1, got {prefetch}")
    if memory_budget is not None:
        rows = memory_budget // (4 * (1 + prefetch) * (d + 1))
        if rows < 1:
            raise ValueError(
                f"memory_budget={memory_budget} cannot hold even one "
                f"{d}-dim row per buffer ({4 * (1 + prefetch) * (d + 1)} "
                f"bytes/row across {1 + prefetch} ring slots)")
        return min(int(rows), max(n, 1))
    return min(default, max(n, 1))


def host_blocks_of(source, rows: int):
    """Numpy host blocks of any source: ``host_blocks`` when the source
    offers it (every built-in host-backed source does), else the device
    stream pulled back block-by-block — so per-shard consumers (the
    sharded executors) can stage each block themselves without assuming a
    source kind."""
    blocks = (source.host_blocks(rows) if hasattr(source, "host_blocks")
              else source.blocks(rows))
    for blk in blocks:
        yield np.asarray(blk, np.float32)


def zip_shard_blocks(shards, rows: int, *, with_weights: bool = False,
                     local_ids=None):
    """Per-shard fold entry point: align the shards' host streams into
    lockstep steps.

    Yields ``(pts (S, rows, d) f32, counts (S,) int64)`` per step — each
    shard's next block, zero-padded to the common ``rows`` shape (the
    executor turns ``counts`` into validity masks), until *every* shard is
    exhausted. A shard that runs out early (unequal shard sizes)
    contributes all-padding steps with ``counts == 0``. The host working
    set is one step — ``S · rows · d`` floats — never a full shard, and
    never n.

    ``with_weights=True`` inserts each shard's per-row f32 weights between
    the points and the counts — ``(pts, w (S, rows), counts)``, padded
    rows at weight 0 — fetched per shard through ``weights_of`` (default
    ones), tracked by per-shard row cursors so the slices stay aligned
    with the blocks.

    ``local_ids`` (a collection of shard indices, or ``None`` for "all")
    is the multi-process form: shards *not* in it are never read — their
    data lives on other controller processes — and their slot in ``pts``
    is ``None``. Their ``counts`` are still exact, computed arithmetically
    from the shard size and a row cursor (every process knows the global
    partition), so masks and step counts agree across processes. The
    yielded ``pts`` is then a list of per-shard ``(rows, d)`` arrays /
    ``None``, which ``compat.global_array_from_shards`` accepts directly.
    """
    if rows < 1:
        raise ValueError(f"rows must be >= 1, got {rows}")
    shards = list(shards)
    if not shards:
        raise ValueError("zip_shard_blocks needs at least one shard")
    local = (set(range(len(shards))) if local_ids is None
             else set(int(i) for i in local_ids))
    sparse = local_ids is not None
    if sparse and with_weights:
        raise NotImplementedError(
            "weighted lockstep steps are not supported with non-local "
            "shards (no weighted multi-process caller exists)")
    d = shards[0].d
    its = [host_blocks_of(s, rows) if s_i in local else None
           for s_i, s in enumerate(shards)]
    pos = [0] * len(shards)
    while True:
        if sparse:
            pts = [None] * len(shards)
        else:
            pts = np.zeros((len(shards), rows, d), np.float32)
        w = np.zeros((len(shards), rows), np.float32) if with_weights else None
        counts = np.zeros((len(shards),), np.int64)
        any_rows = False
        for s, it in enumerate(its):
            if it is None:
                # Non-local shard: exact block accounting without a read.
                nb = min(rows, shards[s].n - pos[s])
                if nb <= 0:
                    continue
                pos[s] += nb
                counts[s] = nb
                any_rows = True
                continue
            blk = next(it, None)
            if blk is None:
                continue
            nb = blk.shape[0]
            if nb > rows:
                raise ValueError(
                    f"shard {s} yielded a {nb}-row block for "
                    f"block_rows={rows}")
            if sparse:
                piece = np.zeros((rows, d), np.float32)
                piece[:nb] = blk
                pts[s] = piece
            else:
                pts[s, :nb] = blk
            if with_weights:
                w[s, :nb] = _source_weights(shards[s], pos[s], nb)
            pos[s] += nb
            counts[s] = nb
            if nb:
                any_rows = True
        if not any_rows:
            return
        if with_weights:
            yield pts, w, counts
        else:
            yield pts, counts


def _source_blocks(source, rows: int, prefetch: int | None):
    """``source.blocks(rows)``, forwarding ``prefetch`` when the source
    supports the keyword (the protocol only requires ``blocks(rows)``)."""
    if prefetch is not None:
        try:
            return source.blocks(rows, prefetch=prefetch)
        except TypeError:
            pass
    return source.blocks(rows)


def _source_weights(source, start: int, rows: int) -> np.ndarray:
    """Per-row f32 weights of rows ``[start, start + rows)``, duck-typed
    (this module imports nothing from ``repro.data`` — cycle direction);
    sources without a ``weights_of`` method get the default-ones path.
    ``repro.data.source.weights_of`` is the public form of the same
    contract."""
    fn = getattr(source, "weights_of", None)
    if fn is None:
        return np.ones((int(rows),), np.float32)
    w = np.asarray(fn(start, rows), np.float32).reshape(-1)
    if w.shape[0] != rows:
        raise ValueError(
            f"weights_of({start}, {rows}) returned {w.shape[0]} weights")
    return w


# -- fused Pallas tiles for the streamed folds (kernels/fused_stream.py) ----
#
# The fold loops below each have a Pallas branch: every block is padded to
# ONE fixed ``ceil(rows/bn)·bn`` shape with validity carried as a kernel
# *operand* (f32 0/1 mask), so a single compilation of the fused tile
# serves the whole stream, ragged tail included — no recompile per tail
# shape (tests/test_engine.py spies on the operand shapes as the
# compile-count proxy). The ref branches are the bitwise oracle; the tile
# kernels reproduce their bits exactly (rows-only tiling — see the
# fused_stream module docstring for why that makes bitwise possible).

def _stream_bn(rows: int, chunk: int | None) -> int:
    """Row tile for the fused streamed kernels: ≤ the kernel default,
    ≤ chunk (the per-pass VMEM knob, floored to the 8-row sublane so an
    explicit chunk is never exceeded), never below 8, and never a
    whole-grid overshoot of a small block."""
    bn = min(fused_stream.DEFAULT_BN, max(8, -(-rows // 8) * 8))
    if chunk is not None:
        bn = min(bn, max(8, (chunk // 8) * 8))
    return bn


def _padded_rows(rows: int, bn: int) -> int:
    return -(-rows // bn) * bn


def _filter_update_tiles(blk, c, d_blk, h_blk, rank: int, chunk: int | None,
                         interpret: bool, w_blk=None):
    """Traced helper: pad one block to the tile grid and run the fused
    filter kernel. Returns ``(d_new (rows,), tops (tiles, rank))`` — the
    d(x,S) min-update for every input row plus each tile's descending
    top-``rank`` of the H-masked candidates. ``w_blk`` (optional per-row
    weights) routes to the weighted sibling kernel, whose extra VMEM
    operand gates ``w <= 0`` rows out of candidacy."""
    rows = blk.shape[0]
    bn = _stream_bn(rows, chunk)
    rows_p = _padded_rows(rows, bn)
    pad = rows_p - rows
    blk_p = jnp.pad(blk, ((0, pad), (0, 0)))
    # Padded lanes: d_s at +BIG (their update is sliced off), H=0 so they
    # never enter the top-k.
    d_p = jnp.pad(d_blk, (0, pad), constant_values=_BIG)
    h_p = jnp.pad(h_blk, (0, pad)).astype(jnp.float32)
    if w_blk is None:
        d_new, tops = fused_stream.fused_filter_blocks(
            blk_p, c, d_p, h_p, rank=rank, bn=bn, interpret=interpret)
    else:
        w_p = jnp.pad(w_blk, (0, pad)).astype(jnp.float32)
        d_new, tops = fused_stream.fused_filter_blocks_w(
            blk_p, c, d_p, h_p, w_p, rank=rank, bn=bn, interpret=interpret)
    return d_new[:rows], tops


def filter_tile_update(blk, c, d_blk, h_blk, *, rank: int,
                       impl: str = "auto", chunk: int | None = None,
                       w_blk=None):
    """One machine-block's share of EIM Rounds 2–3 (traceable, unjitted —
    the executors' shard_map/vmap programs and ``eim_filter_block`` wrap
    it): ``d_new = min(d_blk, d(blk, c)²)`` plus the block's descending
    top-``min(rank, rows)`` of ``where(h_blk, d_new, -inf)``.

    The ref branch is the oracle; the Pallas branch fuses the whole update
    into the streamed tile kernel and reduces the per-tile tops (top-k
    *values* are blocking-invariant, so the results are bitwise equal).
    ``w_blk`` (optional per-row f32 weights) additionally gates ``w <= 0``
    rows out of top-k candidacy; ``w_blk=None`` runs the exact pre-weights
    program.
    """
    use_pallas, interpret = _resolve(impl)
    r = min(rank, d_blk.shape[0])
    if use_pallas:
        d_new, tops = _filter_update_tiles(blk, c, d_blk, h_blk, rank,
                                           chunk, interpret, w_blk=w_blk)
        return d_new, jax.lax.top_k(tops.reshape(-1), r)[0]
    _, dn = assign_nearest(blk, c, impl=impl, chunk=chunk)
    d_new = jnp.minimum(d_blk, dn)
    if w_blk is None:
        cand = jnp.where(h_blk, d_new, _NEG)
    else:
        cand = jnp.where(h_blk & (w_blk > 0), d_new, _NEG)
    return d_new, jax.lax.top_k(cand, r)[0]


@functools.partial(jax.jit, static_argnames=("rank", "impl", "chunk"))
def eim_filter_block(blk, c, d_blk, h_blk, top, w_blk=None, *, rank: int,
                     impl: str, chunk: int | None = None):
    """One super-shard's share of EIM Rounds 2–3, fused and jitted:
    incremental-min d(x, S_new) update + this block's contribution to
    Select's top-k merged into the running ``top`` carry. ``c`` is the
    fixed-capacity S_new buffer (far-sentinel padded) and callers pad
    ``blk``/``d_blk``/``h_blk`` (and ``w_blk`` when weighted) to one fixed
    ``rows`` shape, so one compilation serves every iteration and every
    block — ragged tail included. The executors' streamed filter rounds
    call this; ``impl`` picks the fused Pallas tile vs the jnp oracle
    (bitwise-identical). ``w_blk=None`` (an empty jit pytree leaf, not an
    operand) keeps the unweighted compiled program byte-identical."""
    d_blk, tops = filter_tile_update(blk, c, d_blk, h_blk, rank=rank,
                                     impl=impl, chunk=chunk, w_blk=w_blk)
    return d_blk, merge_top_k(top, tops, rank)


def fold_min_d2(source, c, *, impl: str = "auto", chunk: int | None = None,
                block_rows: int | None = None,
                memory_budget: int | None = None,
                prefetch: int | None = None,
                weighted: bool = False) -> jnp.ndarray:
    """Max over all source points of the min squared distance to ``c`` —
    the squared covering radius, as a streamed fold.

    Per-block maxima combine exactly (max is associative and order-safe),
    so the result is bitwise-identical to the in-memory
    ``max(assign_nearest(x, c)[1])`` for any blocking.

    ``weighted=True`` restricts the max to rows with source weight > 0
    (the weighted instance's support), via the rank-1 case of
    ``fold_top_k_min_d2``; for a source whose weights are all positive —
    unit weights in particular — the value is the same max over the same
    per-block d² multisets, hence bitwise the unweighted fold.
    """
    if weighted:
        top = fold_top_k_min_d2(source, c, 1, impl=impl, chunk=chunk,
                                block_rows=block_rows,
                                memory_budget=memory_budget,
                                prefetch=prefetch, weighted=True)
        # An empty support leaves the -inf sentinel; report radius 0 like
        # the empty-source fold below (real d² are >= 0, so the clamp is
        # the identity on any nonempty support).
        return jnp.maximum(top[0], jnp.float32(0.0))
    rows = resolve_block_rows(source.n, source.d, block_rows=block_rows,
                              memory_budget=memory_budget,
                              prefetch=prefetch or DEFAULT_PREFETCH)
    use_pallas, interpret = _resolve(impl)
    if use_pallas:
        # Fused tile path: the filter kernel with rank=1 and a +BIG d_s
        # carry IS the per-tile max of min-distances; the validity mask
        # gates padded lanes, so one compilation serves the ragged tail.
        bn = _stream_bn(rows, chunk)
        rows_p = _padded_rows(rows, bn)
        d_big = jnp.full((rows_p,), _BIG)
        best = None
        for blk in _source_blocks(source, rows, prefetch):
            nb = blk.shape[0]
            blk_p = jnp.pad(blk, ((0, rows_p - nb), (0, 0)))
            vm = (jnp.arange(rows_p) < nb).astype(jnp.float32)
            _, tops = fused_stream.fused_filter_blocks(
                blk_p, c, d_big, vm, rank=1, bn=bn, interpret=interpret)
            bmax = jnp.max(tops)
            best = bmax if best is None else jnp.maximum(best, bmax)
        if best is None:
            return jnp.float32(0.0)
        return best
    best = None
    for blk in _source_blocks(source, rows, prefetch):
        _, d2 = assign_nearest(blk, c, impl=impl, chunk=chunk)
        bmax = jnp.max(d2)
        best = bmax if best is None else jnp.maximum(best, bmax)
    if best is None:
        return jnp.float32(0.0)
    return best


def fold_top_k_min_d2(source, c, rank: int, *, impl: str = "auto",
                      chunk: int | None = None,
                      block_rows: int | None = None,
                      memory_budget: int | None = None,
                      prefetch: int | None = None,
                      weighted: bool = False) -> jnp.ndarray:
    """Descending top-``rank`` of the min squared distances to ``c`` over
    all source points — the streamed evaluation fold of the outlier
    objective: with ``rank = z + 1``, slot ``z`` is the squared covering
    radius after excluding the ``z`` farthest points
    (``core.outliers.covering_radius_excluding``).

    Top-k *values* are blocking-invariant (``merge_top_k``), so the result
    is bitwise the in-memory ``lax.top_k(assign_nearest(x, c)[1], rank)``
    for any blocking; slots beyond the support size carry the -inf
    sentinel. ``weighted=True`` gates rows with source weight <= 0 out of
    candidacy (they are absent from the weighted instance) — on the Pallas
    branch via the weighted tile's extra VMEM operand, on the ref branch
    via an eager mask; all-positive (e.g. unit) weights leave the
    candidate multiset untouched, hence the bits.
    """
    if rank < 1:
        raise ValueError(f"rank must be >= 1, got {rank}")
    rows = resolve_block_rows(source.n, source.d, block_rows=block_rows,
                              memory_budget=memory_budget,
                              prefetch=prefetch or DEFAULT_PREFETCH)
    use_pallas, interpret = _resolve(impl)
    top = top_k_init(rank)
    off = 0
    if use_pallas:
        bn = _stream_bn(rows, chunk)
        rows_p = _padded_rows(rows, bn)
        d_big = jnp.full((rows_p,), _BIG)
        for blk in _source_blocks(source, rows, prefetch):
            nb = blk.shape[0]
            blk_p = jnp.pad(blk, ((0, rows_p - nb), (0, 0)))
            vm = (jnp.arange(rows_p) < nb).astype(jnp.float32)
            if weighted:
                w_p = np.zeros((rows_p,), np.float32)
                w_p[:nb] = _source_weights(source, off, nb)
                _, tops = fused_stream.fused_filter_blocks_w(
                    blk_p, c, d_big, vm, jnp.asarray(w_p), rank=rank,
                    bn=bn, interpret=interpret)
            else:
                _, tops = fused_stream.fused_filter_blocks(
                    blk_p, c, d_big, vm, rank=rank, bn=bn,
                    interpret=interpret)
            top = merge_top_k(top, tops, rank)
            off += nb
        return top
    for blk in _source_blocks(source, rows, prefetch):
        nb = blk.shape[0]
        _, d2 = assign_nearest(blk, c, impl=impl, chunk=chunk)
        if weighted:
            w = jnp.asarray(_source_weights(source, off, nb))
            d2 = jnp.where(w > 0, d2, _NEG)
        top = merge_top_k(top, d2, rank)
        off += nb
    return top


def assign_nearest_source(source, c, *, impl: str = "auto",
                          chunk: int | None = None,
                          block_rows: int | None = None,
                          memory_budget: int | None = None,
                          prefetch: int | None = None,
                          with_weights: bool = False):
    """Streaming nearest-center assignment over a source.

    Yields ``(idx (rows,) i32, d2 (rows,))`` per block, in row order —
    callers fold (counts, sums, maxima) instead of holding an (n,) result
    on device. Concatenating the yields equals the in-memory
    ``assign_nearest`` output bitwise.

    ``with_weights=True`` appends each block's per-row f32 weights to the
    yield — ``(idx, d2, w (rows,))`` — fetched through the source's
    ``weights_of`` (default ones for unweighted sources), so weighted
    accumulations (``engine`` leaves those to the caller: e.g.
    ``counts.at[idx].add(w)``) ride the same stream with zero extra
    passes. The idx/d2 arithmetic is untouched by the flag.
    """
    rows = resolve_block_rows(source.n, source.d, block_rows=block_rows,
                              memory_budget=memory_budget,
                              prefetch=prefetch or DEFAULT_PREFETCH)
    use_pallas, interpret = _resolve(impl)
    off = 0
    if use_pallas:
        bn = _stream_bn(rows, chunk)
        rows_p = _padded_rows(rows, bn)
        for blk in _source_blocks(source, rows, prefetch):
            nb = blk.shape[0]
            blk_p = jnp.pad(blk, ((0, rows_p - nb), (0, 0)))
            # No mask: padded rows' outputs are sliced off, and the
            # fixed rows_p shape keeps the stream at one compilation.
            idx, d2 = fused_stream.fused_assign_blocks(
                blk_p, c, bn=bn, interpret=interpret)
            if with_weights:
                yield (idx[:nb], d2[:nb],
                       jnp.asarray(_source_weights(source, off, nb)))
            else:
                yield idx[:nb], d2[:nb]
            off += nb
        return
    for blk in _source_blocks(source, rows, prefetch):
        nb = blk.shape[0]
        if with_weights:
            idx, d2 = assign_nearest(blk, c, impl=impl, chunk=chunk)
            yield idx, d2, jnp.asarray(_source_weights(source, off, nb))
        else:
            yield assign_nearest(blk, c, impl=impl, chunk=chunk)
        off += nb


def argmin_dist2_over_source(source, c, *, impl: str = "auto",
                             chunk: int | None = None,
                             block_rows: int | None = None,
                             memory_budget: int | None = None,
                             prefetch: int | None = None) -> jnp.ndarray:
    """``argmin_dist2_over_rows`` over a source: for each center row of
    ``c (m, d)``, the global row index of the nearest source point.

    The fold carries an (m,)-sized running (min, argmin); strict ``<``
    keeps the earliest block on ties, and within a block ``assign_nearest``
    resolves ties to the first row — together matching the global
    first-occurrence semantics of ``jnp.argmin``.
    """
    m = c.shape[0]
    rows = resolve_block_rows(source.n, source.d, block_rows=block_rows,
                              memory_budget=memory_budget,
                              prefetch=prefetch or DEFAULT_PREFETCH)
    use_pallas, interpret = _resolve(impl)
    best_d = jnp.full((m,), _BIG)
    best_i = jnp.zeros((m,), jnp.int32)
    off = 0
    if use_pallas:
        bn = _stream_bn(rows, chunk)
        rows_p = _padded_rows(rows, bn)
        for blk in _source_blocks(source, rows, prefetch):
            nb = blk.shape[0]
            blk_p = jnp.pad(blk, ((0, rows_p - nb), (0, 0)))
            vm = (jnp.arange(rows_p) < nb).astype(jnp.float32)
            bd, bi = fused_stream.fused_argmin_blocks(
                blk_p, c, vm, bn=bn, interpret=interpret)
            take = bd < best_d
            best_d = jnp.where(take, bd, best_d)
            best_i = jnp.where(take, bi + off, best_i)
            off += nb
        return best_i
    for blk in _source_blocks(source, rows, prefetch):
        bi, bd = assign_nearest(c, blk, impl=impl, chunk=chunk)
        take = bd < best_d
        best_d = jnp.where(take, bd, best_d)
        best_i = jnp.where(take, bi + off, best_i)
        off += blk.shape[0]
    return best_i
