"""Pallas TPU kernel: tiled pairwise squared-Euclidean distances.

The k-center hot spot (paper §5: every algorithm's dominant round is
distance computation) is ``D2[i,j] = |x_i - c_j|^2``. On TPU we compute it
as ``|x|^2 + |c|^2 - 2 x c^T`` so the inner product runs on the MXU with
128-aligned tiles, and the rank-1 norm corrections run on the VPU over the
same VMEM-resident tiles (one HBM pass per operand tile instead of three).

Tiling: grid ``(n/bn, m/bm)``; each step loads ``x (bn,d)`` and ``c (bm,d)``
into VMEM and writes one ``(bn,bm)`` output tile. ``d`` is kept un-tiled —
for clustering/embedding workloads d ≤ 8192, so the per-step VMEM working
set is ``(bn+bm)·d·4B + bn·bm·4B`` ≤ ~8.5 MB at the default bn=bm=256,
d=4096 — inside the ~16 MB v5e VMEM budget. Callers with larger d should
chunk d and accumulate (see ops.pairwise_dist2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 256
DEFAULT_BM = 256


def _pairwise_kernel(x_ref, c_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)           # (bn, d)
    c = c_ref[...].astype(jnp.float32)           # (bm, d)
    xn = jnp.sum(x * x, axis=-1, keepdims=True)  # (bn, 1)   VPU
    cn = jnp.sum(c * c, axis=-1, keepdims=True)  # (bm, 1)   VPU
    # MXU matmul; accumulate in f32 regardless of input dtype.
    prod = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (bn, bm)
    out_ref[...] = jnp.maximum(xn + cn.T - 2.0 * prod, 0.0)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def pairwise_dist2(
    x: jnp.ndarray,
    c: jnp.ndarray,
    *,
    bn: int = DEFAULT_BN,
    bm: int = DEFAULT_BM,
    interpret: bool = False,
) -> jnp.ndarray:
    """``(n,d) x (m,d) -> (n,m)`` squared distances. n, m must divide bn, bm
    (ops.py handles padding)."""
    n, d = x.shape
    m = c.shape[0]
    assert n % bn == 0 and m % bm == 0, (n, m, bn, bm)
    grid = (n // bn, m // bm)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, c)
