from .metrics import MetricsLogger, make_eval_fn  # noqa: F401
from .step import (  # noqa: F401
    chunked_softmax_xent,
    cross_entropy,
    init_train_state,
    make_loss_fn,
    make_serve_steps,
    make_train_step,
)
