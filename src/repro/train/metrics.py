"""Training metrics: JSONL logger + evaluation (held-out perplexity).

The logger is append-only JSONL (one dict per line) — trivially tailable,
restart-safe (append mode), and aggregation-friendly. ``evaluate``
computes masked token NLL / perplexity over a deterministic held-out
stream (separate seed space from training — the pipeline is counter-based
so train/eval never overlap).
"""
from __future__ import annotations

import json
import math
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.data import model_batch
from repro.models.config import ModelConfig

from .step import make_loss_fn

EVAL_SEED_OFFSET = 0x0EA1


class MetricsLogger:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._fh = open(path, "a") if path else None
        self.history = []

    def log(self, step: int, **metrics):
        rec = {"step": step, "t": time.time()}
        rec.update({k: (float(v) if hasattr(v, "item") or
                        isinstance(v, (int, float)) else v)
                    for k, v in metrics.items()})
        self.history.append(rec)
        if self._fh:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()

    def close(self):
        if self._fh:
            self._fh.close()


def make_eval_fn(cfg: ModelConfig, *, batch_size: int, seq_len: int,
                 batches: int = 4, seed: int = 0):
    """Returns eval_fn(params) -> {"eval_loss", "eval_ppl"}."""
    loss_fn = make_loss_fn(cfg)
    jitted = jax.jit(lambda p, b: loss_fn(p, b)[0])

    def eval_fn(params) -> Dict[str, float]:
        tot = 0.0
        for i in range(batches):
            b = model_batch(cfg, batch_size, seq_len,
                            seed=seed ^ EVAL_SEED_OFFSET, step=i)
            tot += float(jitted(params, {k: jnp.asarray(v)
                                         for k, v in b.items()}))
        loss = tot / batches
        return {"eval_loss": loss, "eval_ppl": math.exp(min(loss, 30.0))}

    return eval_fn
