"""Train / serve step builders.

``make_train_step``: loss -> grad -> clip -> optimizer, optionally with
gradient accumulation over microbatches (``lax.scan``) for global batches
beyond memory. Cross-entropy is computed with the iota-select trick (no
(B,S,V) one-hot materialization, vocab-sharding friendly: the logsumexp
and label-select reductions over the sharded vocab axis lower to a single
all-reduce each).

``make_serve_steps``: jit-ready prefill / decode closures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import decode_step as _decode_step
from repro.models import forward as _forward
from repro.models import prefill as _prefill
from repro.models.config import ModelConfig
from repro.optim import Optimizer

F32 = jnp.float32


def cross_entropy(logits, labels, mask=None):
    """logits (B,S,V) f32, labels (B,S) int32 -> mean token NLL."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    lab = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    nll = lse - lab
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(F32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


CE_CHUNK = 512  # sequence positions per logits chunk


def chunked_softmax_xent(hidden, head_w, labels, cfg: ModelConfig,
                         chunk: int = CE_CHUNK):
    """Mean token NLL without materializing (B,S,V) logits.

    Scans S in chunks; each (checkpointed) chunk computes its logits,
    logsumexp and label select, contributing a partial NLL sum. Backward
    recomputes one chunk's logits at a time and accumulates the head-weight
    gradient across chunks — peak logits memory drops from O(B·S·V) to
    O(B·chunk·V) (measured ~11 GiB → ~0.3 GiB on qwen2 train_4k, §Perf).
    """
    from repro.models.lm import apply_head

    B, S, D = hidden.shape
    c = min(chunk, S)
    if S % c:
        c = S  # fallback: no chunking for odd sizes
    nb = S // c
    hs = jnp.moveaxis(hidden.reshape(B, nb, c, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nb, c), 1, 0)

    @jax.checkpoint
    def chunk_nll(hc, lc, w):
        logits = apply_head(w, hc, cfg)                   # (B,c,V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        lab = jnp.sum(jnp.where(iota == lc[..., None], logits, 0.0), -1)
        return jnp.sum(lse - lab)

    def body(acc, inp):
        hc, lc = inp
        return acc + chunk_nll(hc, lc, head_w), None

    total, _ = jax.lax.scan(body, jnp.zeros((), F32), (hs, ls))
    return total / (B * S)


def make_loss_fn(cfg: ModelConfig, *, moe_aux_weight: float = 0.01,
                 ce_chunk: int = CE_CHUNK):
    from repro.models.api import head_weights

    def loss_fn(params, batch):
        hidden, aux = _forward(params, batch, cfg, return_hidden=True)
        loss = chunked_softmax_xent(hidden, head_weights(params, cfg),
                                    batch["labels"], cfg, chunk=ce_chunk)
        if cfg.family == "moe":
            loss = loss + moe_aux_weight * aux["moe_aux"]
        return loss, {"loss": loss}
    return loss_fn


def init_train_state(key, cfg: ModelConfig, opt: Optimizer):
    from repro.models import init_params
    params = init_params(key, cfg)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, opt: Optimizer, *,
                    num_microbatches: int = 0,
                    moe_aux_weight: float = 0.01,
                    donate: bool = True):
    """Returns train_step(state, batch) -> (state, metrics).

    ``num_microbatches`` (default: cfg.microbatches) splits the global
    batch on the leading axis and accumulates gradients via ``lax.scan``
    in ``cfg.accum_dtype`` — the standard way to decouple global batch
    from per-device activation memory. A f32 accumulator is a full
    param-sized buffer, so the largest configs accumulate in bf16
    (cfg.accum_dtype, see DESIGN.md §6).
    """
    num_microbatches = num_microbatches or cfg.microbatches
    acc_dt = jnp.dtype(cfg.accum_dtype)
    loss_fn = make_loss_fn(cfg, moe_aux_weight=moe_aux_weight)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, m), grads = grad_fn(params, batch)
        return grads, loss

    def accumulated(params, batch):
        A = num_microbatches

        def slice_batch(x):
            B = x.shape[0]
            return jnp.moveaxis(
                x.reshape((B // A, A) + x.shape[1:]), 1, 0)

        micro = jax.tree.map(slice_batch, batch)

        def body(carry, mb):
            acc, tot = carry
            (loss, m), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(acc_dt) / A, acc, grads)
            return (acc, tot + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        (grads, tot), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        return grads, tot / A

    def train_step(state, batch):
        fn = single if num_microbatches == 1 else accumulated
        grads, loss = fn(state["params"], batch)
        new_params, opt_state = opt.update(grads, state["opt"],
                                           state["params"])
        metrics = {"loss": loss,
                   "grad_norm": opt_state.pop("grad_norm", 0.0),
                   "lr": opt_state.pop("lr", 0.0)}
        return ({"params": new_params, "opt": opt_state,
                 "step": state["step"] + 1}, metrics)

    return train_step


def make_serve_steps(cfg: ModelConfig, S_max: int):
    """Returns (prefill_fn, decode_fn) ready for jit."""
    def prefill_fn(params, batch):
        return _prefill(params, batch, cfg, S_max)

    def decode_fn(params, cache, token):
        return _decode_step(params, cache, token, cfg)

    return prefill_fn, decode_fn
