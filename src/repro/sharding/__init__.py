from .api import (  # noqa: F401
    DP_AXES,
    TP_AXIS,
    constrain,
    constrain_seq,
    current_mesh,
    named_sharding,
    spec,
    use_mesh,
)
from .specs import (  # noqa: F401
    auto_spec,
    batch_pspecs,
    cache_pspecs,
    params_pspecs,
    shardings,
    state_pspecs,
)
