"""Mesh-context sharding API.

Model code calls ``constrain(x, *spec)`` at layout-relevant points; the
constraint is a no-op unless a mesh has been installed via ``use_mesh``
(so single-device smoke tests run the exact same model code). Axis
*logical names* are fixed:

  dp    — batch/data parallel axes, ("pod","data") on the multi-pod mesh
  tp    — tensor-parallel axis, "model"
  none  — replicated

``Policy`` resolves logical names to the installed mesh's physical axes,
dropping axes the mesh doesn't have (a single-pod mesh has no "pod").
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat

_state = threading.local()

DP_AXES = ("pod", "data")
TP_AXIS = "model"


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Install ``mesh`` for the duration; model sharding constraints apply."""
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with compat.set_mesh(mesh):
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def _resolve_axis(mesh: Mesh, logical) -> Optional[object]:
    """logical axis entry -> physical mesh axis name(s) or None."""
    if logical is None:
        return None
    if logical == "dp":
        axes = tuple(a for a in DP_AXES if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    if logical == "tp":
        return TP_AXIS if TP_AXIS in mesh.axis_names else None
    # already-physical name or tuple of names
    if isinstance(logical, (tuple, list)):
        axes = tuple(a for a in logical if a in mesh.axis_names)
        return axes or None
    return logical if logical in mesh.axis_names else None


def spec(mesh: Mesh, *logical) -> P:
    return P(*(_resolve_axis(mesh, l) for l in logical))


def constrain(x, *logical):
    """with_sharding_constraint under the installed mesh (no-op without)."""
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(mesh, *logical))
    )


def named_sharding(mesh: Mesh, *logical) -> NamedSharding:
    return NamedSharding(mesh, spec(mesh, *logical))


def constrain_seq(x, seq_axis: int = 1):
    """Sequence-parallel residual constraint: shard the sequence dim over
    tp between layer regions (Megatron-SP). The TP partial-sum then lowers
    to reduce-scatter (+ later all-gather), halving collective bytes and
    moving them off the critical path. No-op when the mesh lacks tp or the
    sequence doesn't divide (decode S=1)."""
    mesh = current_mesh()
    if mesh is None or TP_AXIS not in mesh.axis_names:
        return x
    tp = mesh.shape[TP_AXIS]
    if tp <= 1 or x.shape[seq_axis] % tp != 0 or x.shape[seq_axis] < tp:
        return x
    logical = [None] * x.ndim
    logical[0] = "dp"
    logical[seq_axis] = "tp"
    return constrain(x, *logical)
