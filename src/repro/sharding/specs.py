"""PartitionSpec assignment for parameters, optimizer state, batches and
KV caches (DESIGN.md §5).

Scheme (logical axes; dp = ("pod","data") where present, tp = "model"):

  embeddings   (V, D)        -> (tp, dp)      vocab TP + FSDP
  lm_head      (D, V)        -> (dp, tp)
  attn wq/wk/wv(D, H·hd)     -> (dp, tp)      head-dim TP, FSDP rows
  attn wo      (H·hd, D)     -> (tp, dp)
  mlp up/gate  (D, F)        -> (dp, tp)
  mlp down     (F, D)        -> (tp, dp)
  moe experts  (E, D, F)     -> (tp, dp, ·)   EP on experts + FSDP
  moe router   (D, E)        -> (dp, ·)
  ssm w_in     (D, ·)        -> (dp, tp)
  ssm w_out    (din, D)      -> (tp, dp)
  1-D params                 -> replicated
  tokens/labels(B, S)        -> (dp, ·)
  KV cache  (L,B,S,KV,hd)    -> (·, dp, tp, ·, ·)   sequence-parallel KV
  ssm state (L,B,nh,N,P)     -> (·, dp, tp, ·, ·)

All layer-stacked params get a leading ``None`` (the scan axis is never
sharded). Optimizer moments reuse the param rules (same trailing path
names & shapes); Adafactor's factored vectors fall back to the
largest-divisible-axis auto rule.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .api import DP_AXES, TP_AXIS, spec as resolve_spec

# (path regex, logical spec for the *trailing* dims of the unstacked param)
_RULES = [
    (r"(embed)$", ("tp", "dp")),
    (r"(lm_head)$", ("dp", "tp")),
    (r"(patch_proj)$", ("dp", "tp")),
    (r"(wq|wk|wv)$", ("dp", "tp")),
    (r"(wo)$", ("tp", "dp")),
    (r"(bq|bk|bv)$", ("tp",)),
    (r"(w_gate|w_up)$", None),   # disambiguated by ndim below (moe vs mlp)
    (r"(w_down)$", None),
    (r"(router)$", ("dp", None)),
    (r"(w_in)$", ("dp", "tp")),
    (r"(w_out)$", ("tp", "dp")),
    (r"(conv)$", (None, "tp")),
]


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _mesh_sizes(mesh: Mesh):
    dp = int(np.prod([mesh.shape[a] for a in DP_AXES if a in mesh.axis_names]))
    tp = mesh.shape.get(TP_AXIS, 1)
    return dp, tp


def _fit_logical(logical, shape, mesh: Mesh):
    """Drop sharding on dims the mesh axes don't divide (jit rejects
    explicit input shardings with non-divisible dims — e.g. vocab 50280
    on a 16-way axis)."""
    dp, tp = _mesh_sizes(mesh)
    size = {"dp": dp, "tp": tp}
    out = []
    for dim, l in zip(shape, logical):
        if l in ("dp", "tp") and (size[l] <= 1 or dim % size[l] != 0):
            out.append(None)
        else:
            out.append(l)
    return tuple(out)


def auto_spec(shape, mesh: Mesh):
    """Fallback: shard the largest dp-divisible axis on dp, then the
    largest remaining tp-divisible axis on tp."""
    dp, tp = _mesh_sizes(mesh)
    entries = [None] * len(shape)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    dp_done = tp_done = False
    for i in order:
        if not dp_done and shape[i] % dp == 0 and shape[i] >= dp:
            entries[i] = "dp"
            dp_done = True
        elif not tp_done and shape[i] % tp == 0 and shape[i] >= tp:
            entries[i] = "tp"
            tp_done = True
    return tuple(entries)


def _param_logical(path_name: str, shape, stacked: bool) -> tuple:
    trailing = shape[1:] if stacked else shape
    for rx, logical in _RULES:
        if re.search(rx, path_name):
            if logical is None:  # w_gate/w_up/w_down: mlp (2-D) vs moe (3-D)
                if path_name.endswith("w_down"):
                    logical = ("tp", None, "dp") if len(trailing) == 3 \
                        else ("tp", "dp")
                else:
                    logical = ("tp", "dp", None) if len(trailing) == 3 \
                        else ("dp", "tp")
            if len(logical) != len(trailing):
                break  # fall through to auto
            return ((None,) + tuple(logical)) if stacked else tuple(logical)
    if len(trailing) <= 1:
        return (None,) * len(shape)
    return None  # signal: use auto_spec


def params_pspecs(params_shapes: Any, mesh: Mesh):
    """Tree of PartitionSpec matching ``params_shapes`` (tree of arrays or
    ShapeDtypeStructs). Layer-stacked subtrees are detected by path prefix
    ('layers' / 'enc_layers' / 'dec_layers' / state trees mirroring them).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for path, leaf in flat:
        name = _leaf_name(path)
        stacked = bool(re.search(r"(^|/)(layers|enc_layers|dec_layers)/",
                                 name + "/") or "layers/" in name)
        logical = _param_logical(name, leaf.shape, stacked)
        if logical is None:
            logical = auto_spec(leaf.shape, mesh)
        logical = _fit_logical(logical, leaf.shape, mesh)
        specs.append(resolve_spec(mesh, *logical))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspecs(batch_shapes: Any, mesh: Mesh):
    """tokens/labels (B,S) -> (dp, None); frames/patches (B,X,D) likewise.
    Batches smaller than the dp axes (long_500k: B=1) stay replicated."""
    def one(leaf):
        nd = len(leaf.shape)
        logical = _fit_logical(("dp",) + (None,) * (nd - 1), leaf.shape, mesh)
        return resolve_spec(mesh, *logical)
    return jax.tree.map(one, batch_shapes)


def cache_pspecs(cache_shapes: Any, mesh: Mesh):
    """KV caches (L,B,S,KV,hd) -> (None, dp, tp, ...): batch over dp,
    *sequence over tp* (sequence-parallel decode attention — the softmax
    reductions over the sharded key axis lower to per-shard partial
    attention + all-reduce combine, the flash-decoding pattern).
    SSM states (L,B,nh,N,P): heads over tp."""
    def one(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        if name.endswith("pos"):
            logical = _fit_logical(("dp",), leaf.shape, mesh)
        elif name in ("k", "v", "xk", "xv") or name.endswith("/k") \
                or name.endswith("/v") or name.endswith("xk") \
                or name.endswith("xv"):
            logical = _fit_logical((None, "dp", "tp", None, None),
                                   leaf.shape, mesh)
        elif name.endswith("state"):
            logical = _fit_logical((None, "dp", "tp", None, None),
                                   leaf.shape, mesh)
        elif name.endswith("conv"):
            logical = _fit_logical((None, "dp", None, "tp"), leaf.shape, mesh)
        else:
            logical = (None,) * nd
        return resolve_spec(mesh, *logical)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def state_pspecs(state_shapes: Any, mesh: Mesh):
    """Optimizer/train-state tree: param-mirroring moments reuse the param
    rules; factored/scalar leaves use the auto rule."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
    specs = []
    for path, leaf in flat:
        name = _leaf_name(path)
        stacked = "layers/" in name
        logical = _param_logical(name, leaf.shape, stacked)
        if logical is None:
            logical = auto_spec(leaf.shape, mesh)
        logical = _fit_logical(logical, leaf.shape, mesh)
        specs.append(resolve_spec(mesh, *logical))
    return jax.tree_util.tree_unflatten(treedef, specs)


def shardings(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
