"""Model configuration schema covering all assigned architecture families.

One frozen dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM
backbones; family-specific fields are zero/empty when unused. Configs are
pure data — layer code dispatches on them, so every architecture is a
config file, not a code fork.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # 0 => attention-free (pure SSM)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads

    # attention details
    qkv_bias: bool = False
    rope_type: str = "rope"          # rope | mrope | sinusoidal | none
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl (t, h, w) head_dim split
    window: int = 0                  # sliding-window size; 0 = global
    global_every: int = 0            # hybrid: every Nth layer is global attn

    # norms / activations
    norm: str = "rmsnorm"            # rmsnorm | layernorm | layernorm_np
    act: str = "silu"                # silu (SwiGLU) | gelu (plain MLP)
    norm_eps: float = 1e-5

    # MoE
    num_experts: int = 0
    top_k: int = 0

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500              # post-conv frame count (frontend stub)

    # modality frontend stubs
    frontend: str = "none"           # none | audio_stub | vision_stub
    num_patches: int = 0             # vlm: patch positions at seq start

    # embeddings / output
    tie_embeddings: bool = True
    logit_softcap: float = 0.0
    residual_scale: float = 1.0      # minicpm depth scaling: 1.4/sqrt(L)
    logit_scale: float = 1.0         # minicpm: 1/(d_model/256)

    # numerics / memory policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "none"              # none | full | dots
    remat_block: int = 0             # >1: two-level checkpointing — only
    #   every remat_block-th layer boundary is saved; the block re-runs in
    #   backward. Cuts the layer-scan carry stack L/k× (1T-param configs).
    microbatches: int = 1            # gradient-accumulation splits of the
    #   global batch in train_step
    accum_dtype: str = "float32"     # grad-accumulator dtype (bf16 on the
    #   largest configs: a f32 accumulator is a full param-sized buffer)
    tp_reduce_bf16: bool = True      # round row-parallel matmul partials
    #   to bf16 before the TP psum — halves the dominant collective bytes
    #   (the MXU still accumulates f32 within each shard; only the cross-
    #   shard sum of <=16 partials is bf16). §Perf iteration 8.
    optimizer: str = "adamw"         # adamw | adafactor (giants)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (roofline MODEL_FLOPS = 6·N·D) ---------------
    def param_counts(self) -> dict:
        """Returns dict with total and active parameter counts."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        H, KV = self.num_heads, self.num_kv_heads
        embed = V * D * (1 if self.tie_embeddings else 2)
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D  # q,k,v,o
        if self.qkv_bias:
            attn += (H + 2 * KV) * hd
        mlp_dense = 3 * D * F if self.act == "silu" else 2 * D * F
        per_layer = 0
        total = embed
        active = embed
        if self.family == "ssm":
            din, N, nh = self.d_inner, self.ssm_state, self.ssm_heads
            # in_proj: z,x,B,C,dt ; out_proj
            inp = D * (2 * din + 2 * N + nh)
            per_layer = inp + din * D + self.ssm_conv * (din + 2 * N) + 2 * nh
            total += L * per_layer
            active += L * per_layer
        elif self.family in ("moe",):
            router = D * self.num_experts
            expert = 3 * D * F
            per_layer = attn + router + self.num_experts * expert
            act_layer = attn + router + self.top_k * expert
            total += L * per_layer
            active += L * act_layer
        elif self.family == "hybrid":
            din, N, nh = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = D * (2 * din + 2 * N + nh) + din * D \
                + self.ssm_conv * (din + 2 * N) + 2 * nh
            per_layer = attn + ssm + mlp_dense
            total += L * per_layer
            active += L * per_layer
        elif self.family == "encdec":
            # enc self-attn + mlp; dec self + cross + mlp
            enc = self.enc_layers * (attn + mlp_dense)
            dec = L * (2 * attn + mlp_dense)
            total += enc + dec
            active += enc + dec
        else:  # dense / vlm
            per_layer = attn + mlp_dense
            total += L * per_layer
            active += L * per_layer
        # norms are negligible; count anyway for dense-family
        return {"total": int(total), "active": int(active)}
