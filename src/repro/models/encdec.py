"""Encoder-decoder backbone (whisper-large-v3 assignment).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, enc_seq, d_model) — the two
conv+GELU downsampling layers of real Whisper live outside this model.
Positions are sinusoidal (Whisper: sinusoidal encoder / learned decoder —
we use sinusoidal for both; recorded as a deviation in DESIGN.md).

Decoder layers: pre-norm self-attention (causal) + cross-attention over
encoder output + GELU MLP. Both stacks run under ``lax.scan``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.sharding import constrain, constrain_seq

from . import layers as L
from .config import ModelConfig

F32 = jnp.float32


def _init_enc_layer(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    return {
        "ln1": L.init_norm(ks[0], cfg),
        "attn": L.init_attention(ks[1], cfg),
        "ln2": L.init_norm(ks[2], cfg),
        "mlp": L.init_mlp(ks[3], cfg),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    return {
        "ln1": L.init_norm(ks[0], cfg),
        "attn": L.init_attention(ks[1], cfg),
        "ln2": L.init_norm(ks[2], cfg),
        "xattn": L.init_attention(ks[3], cfg, cross=True),
        "ln3": L.init_norm(ks[4], cfg),
        "mlp": L.init_mlp(ks[5], cfg),
    }


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    k_enc, k_dec, k_emb, k_n1, k_n2 = jax.random.split(key, 5)
    enc_keys = jax.random.split(k_enc, cfg.enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": L.init_norm(k_n1, cfg),
        "embed": L._dense_init(k_emb, (cfg.vocab_size, cfg.d_model), 1.0,
                               L.pdt(cfg)),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "dec_norm": L.init_norm(k_n2, cfg),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames (B, Se, D) precomputed embeddings -> encoder states."""
    B, Se, D = frames.shape
    x = frames.astype(L.dt(cfg)) + L.sinusoidal_embed(Se, D).astype(L.dt(cfg))
    x = constrain(x, "dp", None, None)
    pos = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))

    def body(x, lp):
        x = constrain_seq(x)
        h = L.apply_norm(lp["ln1"], x, cfg)
        a, _ = L.attention_block(lp["attn"], h, cfg, positions=pos,
                                 bidir=True, rope=False)
        x = constrain_seq(x + a)
        h2 = L.apply_norm(lp["ln2"], x, cfg)
        return constrain_seq(x + L.apply_mlp(lp["mlp"], h2, cfg)), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(params["enc_norm"], x, cfg)


def _dec_embed(params, tokens, cfg: ModelConfig, pos0):
    """Token embeddings + sinusoidal positions starting at pos0 (B,)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(L.dt(cfg))
    posmat = pos0[:, None] + jnp.arange(S)[None, :]
    x = x + L.sinusoidal_at(posmat, cfg.d_model).astype(L.dt(cfg))
    return constrain(x, "dp", None, None)


def forward(params, frames, tokens, cfg: ModelConfig,
            *, return_hidden: bool = False):
    """Teacher-forced training forward. Returns (logits|hidden, aux)."""
    enc_out = encode(params, frames, cfg)
    B, S = tokens.shape
    x = _dec_embed(params, tokens, cfg, jnp.zeros((B,), jnp.int32))
    q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        x = constrain_seq(x)
        h = L.apply_norm(lp["ln1"], x, cfg)
        a, _ = L.attention_block(lp["attn"], h, cfg, positions=q_pos,
                                 rope=False)
        x = constrain_seq(x + a)
        h2 = L.apply_norm(lp["ln2"], x, cfg)
        kv = L.encode_kv(lp["xattn"], enc_out, cfg)
        x = constrain_seq(x + L.cross_attention_block(lp["xattn"], h2, kv, cfg))
        h3 = L.apply_norm(lp["ln3"], x, cfg)
        return constrain_seq(x + L.apply_mlp(lp["mlp"], h3, cfg)), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.apply_norm(params["dec_norm"], x, cfg)
    if return_hidden:
        return constrain(x, "dp", None, None), {}
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T,
                        preferred_element_type=F32)
    return logits, {}


def init_cache(cfg: ModelConfig, B: int, S_max: int):
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    Ld = cfg.num_layers
    return {
        "pos": jnp.zeros((B,), jnp.int32),
        "k": jnp.zeros((Ld, B, S_max, KV, hd), L.dt(cfg)),
        "v": jnp.zeros((Ld, B, S_max, KV, hd), L.dt(cfg)),
        "xk": jnp.zeros((Ld, B, cfg.enc_seq, KV, hd), L.dt(cfg)),
        "xv": jnp.zeros((Ld, B, cfg.enc_seq, KV, hd), L.dt(cfg)),
    }


def prefill(params, frames, tokens, cfg: ModelConfig, S_max: int):
    """Encode + run the prompt through the decoder, building the cache."""
    enc_out = encode(params, frames, cfg)
    B, S = tokens.shape
    x = _dec_embed(params, tokens, cfg, jnp.zeros((B,), jnp.int32))
    q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg)
        a, kv = L.attention_block(lp["attn"], h, cfg, positions=q_pos,
                                  rope=False)
        x = constrain_seq(x + a)
        h2 = L.apply_norm(lp["ln2"], x, cfg)
        xkv = L.encode_kv(lp["xattn"], enc_out, cfg)
        x = constrain_seq(
            x + L.cross_attention_block(lp["xattn"], h2, xkv, cfg))
        h3 = L.apply_norm(lp["ln3"], x, cfg)
        return constrain_seq(x + L.apply_mlp(lp["mlp"], h3, cfg)), \
            {"k": kv[0], "v": kv[1], "xk": xkv[0], "xv": xkv[1]}

    x, kvs = jax.lax.scan(body, x, params["dec_layers"])
    cache = init_cache(cfg, B, S_max)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], kvs["k"].astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], kvs["v"].astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["xk"] = kvs["xk"].astype(cache["xk"].dtype)
    cache["xv"] = kvs["xv"].astype(cache["xv"].dtype)
    x = L.apply_norm(params["dec_norm"], x[:, -1:], cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T,
                        preferred_element_type=F32)
    return logits, cache


def decode_step(params, cache, token, cfg: ModelConfig):
    """One decoder token against (self, cross) caches."""
    B = token.shape[0]
    pos = cache["pos"]
    x = _dec_embed(params, token, cfg, pos)

    def body(x, scanned):
        lp, ck, cv, xk, xv = scanned
        h = L.apply_norm(lp["ln1"], x, cfg)
        a, ck, cv = L.attention_decode(lp["attn"], h, ck, cv, pos, cfg)
        x = x + a
        h2 = L.apply_norm(lp["ln2"], x, cfg)
        x = x + L.cross_attention_block(lp["xattn"], h2, (xk, xv), cfg)
        h3 = L.apply_norm(lp["ln3"], x, cfg)
        return x + L.apply_mlp(lp["mlp"], h3, cfg), (ck, cv)

    x, new_kv = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["k"], cache["v"], cache["xk"],
         cache["xv"]))
    x = L.apply_norm(params["dec_norm"], x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T,
                        preferred_element_type=F32)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = new_kv
    new_cache["pos"] = pos + 1
    return logits, new_cache
