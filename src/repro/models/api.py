"""Family-dispatching model API.

Gives the launcher / train / serve code one uniform surface:

  init_params(key, cfg)
  forward(params, batch, cfg)        -> (logits, aux)
  prefill(params, batch, cfg, S_max) -> (last_logits, cache)
  decode_step(params, cache, token, cfg)
  init_cache(cfg, B, S_max)

``batch`` is a dict: tokens (B,S) always; frames (B,Se,D) for encdec;
patch_embeds (B,P,D) for vlm. Modality frontends are stubs — the framework
receives precomputed embeddings per the assignment.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from . import encdec, lm
from .config import ModelConfig


def init_params(key, cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.init_params(key, cfg)
    return lm.init_params(key, cfg)


def build_mrope_positions(cfg: ModelConfig, B: int, S: int):
    """qwen2-vl M-RoPE positions: (t,h,w) grid for patches, sequential text."""
    P = cfg.num_patches
    side = max(1, int(P ** 0.5)) if P else 1
    pos = jnp.zeros((3, B, S), jnp.int32)
    idx = jnp.arange(S)
    in_patch = idx < P
    t = jnp.where(in_patch, 0, idx - P + 1)
    h = jnp.where(in_patch, idx // side, idx - P + 1)
    w = jnp.where(in_patch, idx % side, idx - P + 1)
    grid = jnp.stack([t, h, w])                       # (3,S)
    return jnp.broadcast_to(grid[:, None, :], (3, B, S))


def forward(params, batch: Dict[str, Any], cfg: ModelConfig,
            *, return_hidden: bool = False):
    if cfg.family == "encdec":
        return encdec.forward(params, batch["frames"], batch["tokens"], cfg,
                              return_hidden=return_hidden)
    if cfg.family == "vlm":
        B, S = batch["tokens"].shape
        return lm.forward(params, batch["tokens"], cfg,
                          positions=build_mrope_positions(cfg, B, S),
                          patch_embeds=batch.get("patch_embeds"),
                          return_hidden=return_hidden)
    return lm.forward(params, batch["tokens"], cfg,
                      return_hidden=return_hidden)


def head_weights(params, cfg: ModelConfig):
    if cfg.family == "encdec":
        return params["embed"].T
    return lm.head_weights(params, cfg)


def prefill(params, batch: Dict[str, Any], cfg: ModelConfig, S_max: int):
    if cfg.family == "encdec":
        return encdec.prefill(params, batch["frames"], batch["tokens"], cfg,
                              S_max)
    if cfg.family == "vlm":
        B, S = batch["tokens"].shape
        return lm.prefill(params, batch["tokens"], cfg, S_max,
                          positions=build_mrope_positions(cfg, B, S),
                          patch_embeds=batch.get("patch_embeds"))
    return lm.prefill(params, batch["tokens"], cfg, S_max)


def decode_step(params, cache, token, cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec.decode_step(params, cache, token, cfg)
    return lm.decode_step(params, cache, token, cfg)


def init_cache(cfg: ModelConfig, B: int, S_max: int):
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, B, S_max)
    return lm.init_cache(cfg, B, S_max)
