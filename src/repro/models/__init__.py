"""Pure-JAX model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM."""
from .api import (  # noqa: F401
    build_mrope_positions,
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
)
from .config import ModelConfig  # noqa: F401
