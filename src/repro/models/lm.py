"""Decoder-only LM covering the dense / MoE / SSM / hybrid / VLM families.

Layers are *stacked* (leading L axis) and applied with ``lax.scan`` so HLO
size and compile time are depth-independent — essential for the 61-layer
trillion-parameter dry-run. Heterogeneous layer schedules (hymba's
global-attention-every-Nth) are expressed as scanned per-layer flag arrays,
not per-layer code.

Entry points
  init_params(key, cfg)
  forward(params, tokens, cfg, ...)        -> (logits, aux)   train/eval
  prefill(params, tokens, cfg, cache_len)  -> (last_logits, cache)
  decode_step(params, cache, token, cfg)   -> (logits, cache)

Cache pytree (decode): dict with per-layer stacked buffers + position.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.sharding import constrain, constrain_seq

from . import layers as L
from .config import ModelConfig

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    fam = cfg.family
    p: Dict[str, Any] = {"ln1": L.init_norm(ks[0], cfg)}
    if fam == "ssm":
        p["ssm"] = L.init_ssm(ks[1], cfg)
        return p
    if fam == "hybrid":
        p["attn"] = L.init_attention(ks[1], cfg)
        p["ssm"] = L.init_ssm(ks[2], cfg)
        p["bnorm_a"] = jnp.ones((cfg.d_model,), F32)
        p["bnorm_s"] = jnp.ones((cfg.d_model,), F32)
        p["ln2"] = L.init_norm(ks[3], cfg)
        p["mlp"] = L.init_mlp(ks[4], cfg)
        return p
    p["attn"] = L.init_attention(ks[1], cfg)
    p["ln2"] = L.init_norm(ks[2], cfg)
    if fam == "moe":
        p["moe"] = L.init_moe(ks[3], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[3], cfg)
    return p


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    k_emb, k_layers, k_head, k_norm = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    params = {
        "embed": L._dense_init(k_emb, (cfg.vocab_size, cfg.d_model), 1.0,
                               L.pdt(cfg)),
        "layers": stacked,
        "final_norm": L.init_norm(k_norm, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(
            k_head, (cfg.d_model, cfg.vocab_size), 1.0, L.pdt(cfg))
    if cfg.frontend == "vision_stub":
        params["patch_proj"] = L._dense_init(
            jax.random.fold_in(k_emb, 1), (cfg.d_model, cfg.d_model), 1.0,
            L.pdt(cfg))
    return params


def _layer_flags(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) bool — True where the layer uses *global* attention."""
    idx = jnp.arange(cfg.num_layers)
    if cfg.window and cfg.global_every:
        return idx % cfg.global_every == 0
    return jnp.ones((cfg.num_layers,), bool) if not cfg.window \
        else jnp.zeros((cfg.num_layers,), bool)


# ---------------------------------------------------------------------------
# Blocks (single layer, scanned)
# ---------------------------------------------------------------------------

def _block_fwd(x, lp, is_global, cfg: ModelConfig, positions):
    """One transformer block over the full sequence. Returns (x', (k,v))."""
    fam = cfg.family
    rs = cfg.residual_scale
    kv = None
    x = constrain_seq(x)
    if fam == "ssm":
        h = L.apply_norm(lp["ln1"], x, cfg)
        out, _, _ = L.ssm_block(lp["ssm"], h, cfg)
        return constrain_seq(x + rs * out), kv
    h = L.apply_norm(lp["ln1"], x, cfg)
    if fam == "hybrid":
        # global/window selected per layer via a traced window scalar —
        # one attend call serves both layer kinds under the layer scan.
        win = jnp.where(is_global, 0, cfg.window)
        attn_out, kv = L.attention_block(lp["attn"], h, cfg,
                                         positions=positions, window=win)
        ssm_out, _, _ = L.ssm_block(lp["ssm"], h, cfg)
        na = attn_out * jax.lax.rsqrt(
            jnp.mean(jnp.square(attn_out.astype(F32)), -1, keepdims=True)
            + cfg.norm_eps) * lp["bnorm_a"]
        ns = ssm_out * jax.lax.rsqrt(
            jnp.mean(jnp.square(ssm_out.astype(F32)), -1, keepdims=True)
            + cfg.norm_eps) * lp["bnorm_s"]
        mix = (0.5 * (na + ns)).astype(x.dtype)
        x = constrain_seq(x + rs * mix)
        h2 = L.apply_norm(lp["ln2"], x, cfg)
        return constrain_seq(x + rs * L.apply_mlp(lp["mlp"], h2, cfg)), kv
    attn_out, kv = L.attention_block(lp["attn"], h, cfg, positions=positions,
                                     window=cfg.window if not cfg.global_every else 0)
    x = constrain_seq(x + rs * attn_out)
    h2 = L.apply_norm(lp["ln2"], x, cfg)
    if fam == "moe":
        mo, aux = L.apply_moe(lp["moe"], h2, cfg)
        return constrain_seq(x + rs * mo), (kv, aux)
    return constrain_seq(x + rs * L.apply_mlp(lp["mlp"], h2, cfg)), kv


def _block_decode(x, lp, cache_l, is_global, pos, cfg: ModelConfig,
                  rope_pos=None):
    """One block, single-token decode. cache_l: per-layer cache slices."""
    fam = cfg.family
    rs = cfg.residual_scale
    new_cache = dict(cache_l)
    h = L.apply_norm(lp["ln1"], x, cfg)
    if fam == "ssm":
        out, st, cv = L.ssm_block(lp["ssm"], h, cfg, state=cache_l["state"],
                                  conv_cache=cache_l["conv"])
        new_cache.update(state=st, conv=cv)
        return x + rs * out, new_cache
    if fam == "hybrid":
        win = jnp.where(is_global, 0, cfg.window)
        attn_out, ck, cv = L.attention_decode(lp["attn"], h, cache_l["k"],
                                              cache_l["v"], pos, cfg,
                                              window=win, rope_pos=rope_pos)
        new_cache.update(k=ck, v=cv)
        ssm_out, st, cv = L.ssm_block(lp["ssm"], h, cfg,
                                      state=cache_l["state"],
                                      conv_cache=cache_l["conv"])
        new_cache.update(state=st, conv=cv)
        na = attn_out * jax.lax.rsqrt(
            jnp.mean(jnp.square(attn_out.astype(F32)), -1, keepdims=True)
            + cfg.norm_eps) * lp["bnorm_a"]
        ns = ssm_out * jax.lax.rsqrt(
            jnp.mean(jnp.square(ssm_out.astype(F32)), -1, keepdims=True)
            + cfg.norm_eps) * lp["bnorm_s"]
        x = x + rs * (0.5 * (na + ns)).astype(x.dtype)
        h2 = L.apply_norm(lp["ln2"], x, cfg)
        return x + rs * L.apply_mlp(lp["mlp"], h2, cfg), new_cache
    attn_out, ck, cv = L.attention_decode(
        lp["attn"], h, cache_l["k"], cache_l["v"], pos, cfg,
        window=cfg.window if not cfg.global_every else 0, rope_pos=rope_pos)
    new_cache.update(k=ck, v=cv)
    x = x + rs * attn_out
    h2 = L.apply_norm(lp["ln2"], x, cfg)
    if fam == "moe":
        mo, _ = L.apply_moe(lp["moe"], h2, cfg)
        return x + rs * mo, new_cache
    return x + rs * L.apply_mlp(lp["mlp"], h2, cfg), new_cache


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _run_layers(body, x, layers, flags, cfg: ModelConfig):
    """Apply the scanned layer stack with the configured remat scheme.

    ``remat_block > 1`` enables two-level checkpointing: layers are grouped
    into blocks; only block-boundary activations are saved (L/k instead of
    L carries) and each block's layers recompute in backward. This is what
    lets the 61-layer d=7168 config fit — the per-layer carry stack alone
    is 53 GiB/device at B_loc=16 (§Perf iteration 5). A remainder of
    L mod k layers runs as a plain per-layer-checkpointed scan.
    Returns (x, summed_aux).
    """
    k = cfg.remat_block
    Lh = cfg.num_layers
    if k and k > 1 and Lh >= 2 * k:
        nb, rem = Lh // k, Lh % k
        take = lambda a, lo, hi: jax.tree.map(lambda v: v[lo:hi], a)
        blk_layers = jax.tree.map(
            lambda v: v[: nb * k].reshape((nb, k) + v.shape[1:]), layers)
        blk_flags = flags[: nb * k].reshape(nb, k)

        # nested checkpoint: the inner per-layer checkpoint keeps the block
        # recompute from stashing layer internals (MoE token gathers +
        # gathered expert weights measured at ~35 GiB/block on kimi-k2);
        # only the (B,S,D) carry survives per layer.
        inner = jax.checkpoint(body)

        def run_block(x, lp_blk, fl_blk):
            return jax.lax.scan(inner, x, (lp_blk, fl_blk))

        def outer(x, scanned):
            lp_blk, fl_blk = scanned
            x, auxs = jax.checkpoint(run_block)(x, lp_blk, fl_blk)
            return x, jnp.sum(auxs)

        x, aux1 = jax.lax.scan(outer, x, (blk_layers, blk_flags))
        aux_total = jnp.sum(aux1)
        if rem:
            x, aux2 = jax.lax.scan(_maybe_remat(body, cfg), x,
                                   (take(layers, nb * k, Lh),
                                    flags[nb * k :]))
            aux_total = aux_total + jnp.sum(aux2)
        return x, aux_total
    x, auxs = jax.lax.scan(_maybe_remat(body, cfg), x, (layers, flags))
    return x, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg: ModelConfig, patch_embeds=None):
    x = params["embed"][tokens].astype(L.dt(cfg))
    if patch_embeds is not None:
        pe = L.matmul(patch_embeds.astype(L.dt(cfg)),
                      params["patch_proj"]).astype(L.dt(cfg))
        npatch = pe.shape[1]
        x = jnp.concatenate([pe, x[:, npatch:]], axis=1)
    return constrain(x, "dp", None, None)


def head_weights(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def apply_head(w, x, cfg: ModelConfig):
    """hidden (B,S,D) x head (D,V) -> f32 logits, with arch scaling."""
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=F32)
    logits = logits * cfg.logit_scale
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return constrain(logits, "dp", None, "tp")


def _logits(params, x, cfg: ModelConfig):
    x = L.apply_norm(params["final_norm"], x, cfg)
    return apply_head(head_weights(params, cfg), x, cfg)


def default_positions(cfg: ModelConfig, B: int, S: int):
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    if cfg.rope_type == "mrope":
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


# ---------------------------------------------------------------------------
# Forward (train / eval / prefill-logits)
# ---------------------------------------------------------------------------

def forward(params, tokens, cfg: ModelConfig, *, positions=None,
            patch_embeds=None, return_hidden: bool = False):
    """tokens (B,S) -> (logits (B,S,V) f32, aux dict).

    ``return_hidden=True`` yields the final-norm'd hidden states instead
    of logits — the training loss path pairs this with a *chunked*
    softmax-xent so the (B,S,V) logits are never materialized at once.
    """
    B, S = tokens.shape
    x = _embed(params, tokens, cfg, patch_embeds)
    if positions is None:
        positions = default_positions(cfg, B, S)
    flags = _layer_flags(cfg)

    def body(x, scanned):
        lp, flag = scanned
        out, extra = _block_fwd(x, lp, flag, cfg, positions)
        aux = extra[1] if isinstance(extra, tuple) and cfg.family == "moe" else 0.0
        return out, aux

    x, aux_total = _run_layers(body, x, params["layers"], flags, cfg)
    aux = {"moe_aux": aux_total if cfg.family == "moe" else 0.0}
    if return_hidden:
        x = L.apply_norm(params["final_norm"], x, cfg)
        return constrain(x, "dp", None, None), aux
    logits = _logits(params, x, cfg)
    return logits, aux


# ---------------------------------------------------------------------------
# KV / state cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, B: int, S_max: int, dtype=None):
    dtype = dtype or L.dt(cfg)
    Lh = cfg.num_layers
    c: Dict[str, Any] = {"pos": jnp.zeros((B,), jnp.int32)}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "hybrid"):
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        c["k"] = jnp.zeros((Lh, B, S_max, KV, hd), dtype)
        c["v"] = jnp.zeros((Lh, B, S_max, KV, hd), dtype)
    if fam in ("ssm", "hybrid"):
        nh, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        c["state"] = jnp.zeros((Lh, B, nh, N, P), F32)
        c["conv"] = jnp.zeros((Lh, B, cfg.ssm_conv - 1,
                               cfg.d_inner + 2 * cfg.ssm_state), dtype)
    return c


def _cache_layers(cache):
    return {k: v for k, v in cache.items() if k != "pos"}


def prefill(params, tokens, cfg: ModelConfig, S_max: int, *,
            positions=None, patch_embeds=None):
    """Run the full prompt, build the decode cache. Returns (last_logits, cache)."""
    B, S = tokens.shape
    x = _embed(params, tokens, cfg, patch_embeds)
    if positions is None:
        positions = default_positions(cfg, B, S)
    flags = _layer_flags(cfg)
    fam = cfg.family

    def body(x, scanned):
        lp, flag = scanned
        out, extra = _block_fwd(x, lp, flag, cfg, positions)
        ys = {}
        if fam in ("dense", "moe", "vlm", "hybrid"):
            kv = extra[0] if isinstance(extra, tuple) and fam == "moe" else extra
            ys = {"k": kv[0], "v": kv[1]}
        return out, ys

    x, kvs = jax.lax.scan(body, x, (params["layers"], flags))
    cache = init_cache(cfg, B, S_max)
    cache["pos"] = jnp.full((B,), S, jnp.int32)
    if "k" in cache:
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], kvs["k"].astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], kvs["v"].astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    if fam in ("ssm", "hybrid"):
        # re-run states through a scan that also returns final ssm state
        # (ssm state comes out of _block_fwd only as needed; for prefill we
        # recompute states layer-by-layer below)
        cache = _prefill_ssm_states(params, tokens, cfg, cache,
                                    patch_embeds=patch_embeds,
                                    positions=positions)
    logits = _logits(params, x[:, -1:, :], cfg)
    return logits, cache


def _prefill_ssm_states(params, tokens, cfg, cache, *, positions, patch_embeds):
    """Populate ssm state/conv caches by scanning blocks with state outputs."""
    B, S = tokens.shape
    x = _embed(params, tokens, cfg, patch_embeds)
    flags = _layer_flags(cfg)

    def body(x, scanned):
        lp, flag = scanned
        h = L.apply_norm(lp["ln1"], x, cfg)
        if cfg.family == "ssm":
            out, st, cv = L.ssm_block(lp["ssm"], h, cfg)
            return x + cfg.residual_scale * out, {"state": st, "conv": cv}
        # hybrid
        win = jnp.where(flag, 0, cfg.window)
        attn_out, _ = L.attention_block(lp["attn"], h, cfg,
                                        positions=positions, window=win)
        ssm_out, st, cv = L.ssm_block(lp["ssm"], h, cfg)
        na = attn_out * jax.lax.rsqrt(
            jnp.mean(jnp.square(attn_out.astype(F32)), -1, keepdims=True)
            + cfg.norm_eps) * lp["bnorm_a"]
        ns = ssm_out * jax.lax.rsqrt(
            jnp.mean(jnp.square(ssm_out.astype(F32)), -1, keepdims=True)
            + cfg.norm_eps) * lp["bnorm_s"]
        x = x + cfg.residual_scale * (0.5 * (na + ns)).astype(x.dtype)
        h2 = L.apply_norm(lp["ln2"], x, cfg)
        x = x + cfg.residual_scale * L.apply_mlp(lp["mlp"], h2, cfg)
        return x, {"state": st, "conv": cv}

    _, states = jax.lax.scan(body, x, (params["layers"], flags))
    cache["state"] = states["state"]
    cache["conv"] = states["conv"]
    return cache


def decode_step(params, cache, token, cfg: ModelConfig):
    """token (B,1) int32 -> (logits (B,1,V), new cache). pos = cache['pos']."""
    B = token.shape[0]
    pos = cache["pos"]
    x = _embed(params, token, cfg)
    flags = _layer_flags(cfg)
    # M-RoPE text tokens sit (num_patches-1) behind their cache slot in
    # rope-position space (the patch grid occupies one temporal step).
    rope_pos = pos - (cfg.num_patches - 1) \
        if cfg.rope_type == "mrope" and cfg.num_patches else pos

    def body(x, scanned):
        lp, cache_l, flag = scanned
        out, new_cache_l = _block_decode(x, lp, cache_l, flag, pos, cfg,
                                         rope_pos=rope_pos)
        return out, new_cache_l

    x, new_layer_caches = jax.lax.scan(
        body, x, (params["layers"], _cache_layers(cache), flags))
    logits = _logits(params, x, cfg)
    new_cache = dict(new_layer_caches)
    new_cache["pos"] = pos + 1
    return logits, new_cache
