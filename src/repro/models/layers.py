"""Building-block layers for every assigned architecture family.

Pure-function style: ``init_*`` builds a param dict, ``apply``-style
functions consume it. All matmuls accumulate in f32
(``preferred_element_type``); params/computation dtypes come from the
ModelConfig. Sharding is expressed through ``repro.sharding.constrain``
with logical axes (dp = batch axes, tp = tensor axis) and is a no-op on a
single device.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.sharding import constrain

from .config import ModelConfig

Params = Dict[str, Any]
F32 = jnp.float32


def dt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _dense_init(key, shape, scale: float, dtype) -> jnp.ndarray:
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, F32) * std).astype(dtype)


def matmul(x, w):
    """bf16-safe matmul with f32 accumulation."""
    return jnp.einsum("...d,df->...f", x, w, preferred_element_type=F32)


def matmul_c(x, w, cfg):
    """Column-parallel matmul with compute-dtype output: its *transpose*
    (the dx = dout·Wᵀ backward) contracts over the tp-sharded feature dim,
    so the cotangent partial-sum inherits this output dtype — f32 output
    doubles the dominant backward collective (§Perf iteration 8)."""
    return jnp.einsum("...d,df->...f", x, w,
                      preferred_element_type=dt(cfg) if cfg.tp_reduce_bf16
                      else F32)


def matmul_rp(x, w, cfg):
    """Row-parallel (TP-contracted) matmul: the cross-shard partial sum is
    the dominant train-step collective, so partials are rounded to the
    compute dtype before the psum when cfg.tp_reduce_bf16 (halves the
    collective bytes; per-shard accumulation stays f32 on the MXU)."""
    out_dt = dt(cfg) if cfg.tp_reduce_bf16 else F32
    return jnp.einsum("...d,df->...f", x, w, preferred_element_type=out_dt)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(key, cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), F32)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), F32), "bias": jnp.zeros((d,), F32)}
    if cfg.norm == "layernorm_np":   # olmo: non-parametric LN
        return {}
    raise ValueError(cfg.norm)


def apply_norm(params: Params, x, cfg: ModelConfig):
    x32 = x.astype(F32)
    if cfg.norm == "rmsnorm":
        x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + cfg.norm_eps)
        return (x32 * params["scale"]).astype(x.dtype)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    x32 = (x32 - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    if cfg.norm == "layernorm":
        x32 = x32 * params["scale"] + params["bias"]
    return x32.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE, M-RoPE, sinusoidal)
# ---------------------------------------------------------------------------

def _rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=F32) / hd))


def rope_cos_sin(positions, hd: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, hd/2)."""
    ang = positions[..., None].astype(F32) * _rope_freqs(hd, theta)
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions3, sections, hd: int, theta: float):
    """M-RoPE (qwen2-vl): positions3 (3, B, S); head_dim split into
    (temporal, height, width) frequency sections of sizes ``sections``
    (in half-dim units, sum = hd/2)."""
    cos_t, sin_t = rope_cos_sin(positions3, hd, theta)  # (3,B,S,hd/2)
    idx = []
    for comp, size in enumerate(sections):
        idx += [comp] * size
    sel = jnp.asarray(idx, jnp.int32)                    # (hd/2,)
    comp = jnp.arange(len(sel))
    cos = cos_t[sel, :, :, comp]                         # -> (hd/2, B, S)
    sin = sin_t[sel, :, :, comp]
    return jnp.moveaxis(cos, 0, -1), jnp.moveaxis(sin, 0, -1)


def apply_rope(x, cos, sin):
    """x (B,S,H,hd); cos/sin (B,S,hd/2) — rotate-half convention."""
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    c, s = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


def sinusoidal_embed(S: int, d: int):
    pos = jnp.arange(S, dtype=F32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=F32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    emb = jnp.zeros((S, d), F32).at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return emb


def sinusoidal_at(positions, d: int):
    """Sinusoidal embeddings computed directly at ``positions (B,S)`` —
    no materialized position table (decode positions can reach 500k)."""
    dim = jnp.arange(0, d, 2, dtype=F32)
    ang = positions[..., None].astype(F32) / jnp.power(10000.0, dim / d)
    B, S = positions.shape
    emb = jnp.zeros((B, S, d), F32)
    return emb.at[..., 0::2].set(jnp.sin(ang)).at[..., 1::2].set(jnp.cos(ang))


# ---------------------------------------------------------------------------
# Attention (GQA; causal / window / bidirectional / cross; cached decode)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (D, H * hd), 1.0, pdt(cfg)),
        "wk": _dense_init(ks[1], (D, KV * hd), 1.0, pdt(cfg)),
        "wv": _dense_init(ks[2], (D, KV * hd), 1.0, pdt(cfg)),
        "wo": _dense_init(ks[3], (H * hd, D), 1.0, pdt(cfg)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), F32)
        p["bk"] = jnp.zeros((KV * hd,), F32)
        p["bv"] = jnp.zeros((KV * hd,), F32)
    return p


def _qkv(params, x, xkv, cfg: ModelConfig):
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = matmul_c(x, params["wq"], cfg)
    k = matmul_c(xkv, params["wk"], cfg)
    v = matmul_c(xkv, params["wv"], cfg)
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, x.shape[1], H, hd).astype(dt(cfg))
    k = k.reshape(B, xkv.shape[1], KV, hd).astype(dt(cfg))
    v = v.reshape(B, xkv.shape[1], KV, hd).astype(dt(cfg))
    return q, k, v


ATTN_CHUNK = 512  # q-block size for the chunked (flash-style) path
# (1024 -> 512 measured: peak f32 score transients halve on train_4k with
#  <1% extra scan overhead; §Perf iteration 2)


def _mask_block(q_pos, k_idx, window, bidir: bool):
    """Visibility mask (B,bq,Sk) from per-token query positions.

    ``q_pos (B,bq)`` int32 absolute positions, ``k_idx (Sk,)`` cache/key
    indices, ``window`` traced int32 scalar (0 = unbounded lookback).
    Computing masks from indices (instead of materializing (S,S) bools)
    keeps memory O(bq·Sk) and lets window/global layers share one attend
    (the hybrid arch selects window per layer as a traced value).
    """
    if bidir:
        return jnp.ones(q_pos.shape + k_idx.shape, bool)
    m = k_idx[None, None, :] <= q_pos[:, :, None]
    m &= (window <= 0) | (k_idx[None, None, :] > q_pos[:, :, None] - window)
    return m


def _attend_block(qc, k, v, q_pos_c, k_idx, window, bidir, cfg: ModelConfig):
    """One q-chunk of attention. qc (B,bq,KV,G,hd); k/v (B,Sk,KV,hd)."""
    hd = qc.shape[-1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qc, k,
                        preferred_element_type=F32) / math.sqrt(hd)
    if cfg.logit_softcap > 0:
        scores = cfg.logit_softcap * jnp.tanh(scores / cfg.logit_softcap)
    mask = _mask_block(q_pos_c, k_idx, window, bidir)     # (B,bq,Sk)
    neg = jnp.finfo(F32).min
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    w = jax.nn.softmax(scores, axis=-1).astype(dt(cfg))
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v, preferred_element_type=F32)


def attend(q, k, v, cfg: ModelConfig, *, q_pos, window=0, bidir: bool = False,
           chunk: int = ATTN_CHUNK):
    """Memory-bounded attention. q (B,Sq,H,hd), k/v (B,Sk,KV,hd).

    GQA via a (KV, group) reshape — no materialized k/v repeat. Scores are
    computed per q-chunk (``lax.scan``) so peak activation memory is
    O(B·H·chunk·Sk), never O(S²) — the pure-XLA analogue of a flash kernel
    and the layout the TPU fusion pipeline handles well.

    ``q_pos (B,Sq)``: absolute position of each query (mask source).
    ``window``: python int or traced scalar; 0 = global causal.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    # NOTE: no explicit q/k/v constraints here — the column-sharded (tp)
    # projection weights propagate head sharding through the reshape, and
    # XLA factors tp across (KV, G) when KV < tp. Pinning tp onto the KV
    # axis forces involuntary full remat (measured: §Perf iteration 1).
    q = q.reshape(B, Sq, KV, G, hd)
    k_idx = jnp.arange(k.shape[1], dtype=jnp.int32)
    window = jnp.asarray(window, jnp.int32)

    if Sq <= chunk:
        out = _attend_block(q, k, v, q_pos, k_idx, window, bidir, cfg)
    else:
        S0 = Sq
        if Sq % chunk:
            # pad queries to a chunk multiple; padded rows get q_pos=0 so
            # they attend exactly key 0 (well-defined softmax, no NaNs in
            # the trimmed rows' backward), then are sliced away.
            pad = chunk - Sq % chunk
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
            q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)))
            Sq = Sq + pad
        nb = Sq // chunk
        qs = jnp.moveaxis(q.reshape(B, nb, chunk, KV, G, hd), 1, 0)
        ps = jnp.moveaxis(q_pos.reshape(B, nb, chunk), 1, 0)

        # checkpoint the chunk body: without it the chunk scan stacks its
        # backward residuals (broadcast masks + softmax weights) over all
        # chunks — measured 1.9 GiB/chunk/layer on qwen2 train_4k (§Perf
        # iteration 1). Recomputing one chunk's scores in backward is
        # ~free next to the FLOPs it saves from HBM.
        blk = jax.checkpoint(
            lambda qc, pc, k_, v_, w_: _attend_block(
                qc, k_, v_, pc, k_idx, w_, bidir, cfg))

        def body(_, inp):
            qc, pc = inp
            return None, blk(qc, pc, k, v, window)

        _, outs = jax.lax.scan(body, None, (qs, ps))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, G, hd)[:, :S0]
        Sq = S0
    out = out.reshape(B, Sq, H * hd).astype(dt(cfg))
    return constrain(out, "dp", None, "tp")


def attention_block(params, x, cfg: ModelConfig, *, positions, q_pos=None,
                    window=0, bidir: bool = False, rope: bool = True):
    """Self-attention over the full sequence (train/prefill).

    ``positions`` feed RoPE ((B,S), or (3,B,S) for M-RoPE); ``q_pos`` feeds
    the visibility mask (defaults to arange). ``window`` may be a traced
    scalar (hybrid layers select global/window per layer).
    Returns (out, (k, v)).
    """
    B, S = x.shape[:2]
    q, k, v = _qkv(params, x, x, cfg)
    if rope and cfg.rope_type == "rope":
        cos, sin = rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    elif rope and cfg.rope_type == "mrope":
        cos, sin = mrope_cos_sin(positions, cfg.mrope_sections,
                                 cfg.resolved_head_dim, cfg.rope_theta)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    # GQA SPMD note (§Perf iteration 6): the (KV, group) factorization
    # cannot carry the tp axis across two dims under PartitionSpec, and
    # XLA's fallback partial-sums the (B,H,Sq,Sk) *scores* over tp —
    # measured 672 GiB/step of all-reduce on qwen2 train_4k. For
    # train/prefill we instead broadcast k/v to the full head count (a
    # ~117 MB/layer broadcast) so q/k/v/scores all shard cleanly on the
    # head axis and attention is collective-free. The cache keeps the
    # compact KV heads.
    kv_cache = (k, v)
    G = cfg.num_heads // max(cfg.num_kv_heads, 1)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    q = constrain(q, "dp", None, "tp", None)
    out = attend(q, k, v, cfg, q_pos=q_pos, window=window, bidir=bidir)
    return matmul_rp(out, params["wo"], cfg).astype(dt(cfg)), kv_cache


def attention_decode(params, x, cache_k, cache_v, pos, cfg: ModelConfig,
                     *, window=0, rope_pos=None):
    """One-token decode. x (B,1,D); cache (B,S_max,KV,hd); pos (B,).

    ``pos`` indexes the cache slot / visibility mask; ``rope_pos`` (default
    = pos) feeds the rotary embedding — they differ for M-RoPE text tokens,
    whose rope position is shifted by the patch-grid size.
    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    rp = pos if rope_pos is None else rope_pos
    q, k, v = _qkv(params, x, x, cfg)
    if cfg.rope_type == "rope":
        cos, sin = rope_cos_sin(rp[:, None], cfg.resolved_head_dim, cfg.rope_theta)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    elif cfg.rope_type == "mrope":
        pos3 = jnp.broadcast_to(rp[None, :, None], (3,) + rp.shape + (1,))
        cos, sin = mrope_cos_sin(pos3, cfg.mrope_sections,
                                 cfg.resolved_head_dim, cfg.rope_theta)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    # insert k,v at pos (dynamic per-batch index)
    bidx = jnp.arange(x.shape[0])
    cache_k = cache_k.at[bidx, pos].set(k[:, 0])
    cache_v = cache_v.at[bidx, pos].set(v[:, 0])
    cache_k = constrain(cache_k, "dp", "tp", None, None)
    cache_v = constrain(cache_v, "dp", "tp", None, None)
    out = attend(q, cache_k, cache_v, cfg, q_pos=pos[:, None], window=window)
    return matmul_rp(out, params["wo"], cfg).astype(dt(cfg)), cache_k, cache_v


def cross_attention_block(params, x, enc_kv, cfg: ModelConfig):
    """Decoder cross-attention; enc_kv = (k,v) precomputed from encoder."""
    B, Sq = x.shape[:2]
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    q = matmul(x, params["wq"]).reshape(B, Sq, H, hd).astype(dt(cfg))
    k, v = enc_kv
    q_pos = jnp.zeros((B, Sq), jnp.int32)
    out = attend(q, k, v, cfg, q_pos=q_pos, bidir=True)
    return matmul_rp(out, params["wo"], cfg).astype(dt(cfg))


def encode_kv(params, enc_out, cfg: ModelConfig):
    B, Se = enc_out.shape[:2]
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = matmul(enc_out, params["wk"]).reshape(B, Se, KV, hd).astype(dt(cfg))
    v = matmul(enc_out, params["wv"]).reshape(B, Se, KV, hd).astype(dt(cfg))
    return k, v


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig) -> Params:
    D, Fd = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {
            "w_gate": _dense_init(ks[0], (D, Fd), 1.0, pdt(cfg)),
            "w_up": _dense_init(ks[1], (D, Fd), 1.0, pdt(cfg)),
            "w_down": _dense_init(ks[2], (Fd, D), 1.0, pdt(cfg)),
        }
    return {
        "w_up": _dense_init(ks[0], (D, Fd), 1.0, pdt(cfg)),
        "b_up": jnp.zeros((Fd,), F32),
        "w_down": _dense_init(ks[1], (Fd, D), 1.0, pdt(cfg)),
        "b_down": jnp.zeros((D,), F32),
    }


def apply_mlp(params, x, cfg: ModelConfig):
    if cfg.act == "silu":
        h = jax.nn.silu(matmul_c(x, params["w_gate"], cfg)) \
            * matmul_c(x, params["w_up"], cfg)
        h = constrain(h.astype(dt(cfg)), "dp", None, "tp")
        return matmul_rp(h, params["w_down"], cfg).astype(dt(cfg))
    h = jax.nn.gelu(matmul_c(x, params["w_up"], cfg) + params["b_up"])
    h = constrain(h.astype(dt(cfg)), "dp", None, "tp")
    return (matmul_rp(h, params["w_down"], cfg) + params["b_down"]).astype(dt(cfg))


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based SPMD dispatch, GShard-style)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> Params:
    D, Fd, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (D, E), 1.0, F32),
        "w_gate": _dense_init(ks[1], (E, D, Fd), 1.0, pdt(cfg)),
        "w_up": _dense_init(ks[2], (E, D, Fd), 1.0, pdt(cfg)),
        "w_down": _dense_init(ks[3], (E, Fd, D), 1.0, pdt(cfg)),
    }


def _moe_math(xf, router, wg, wu, wd, cfg: ModelConfig,
              capacity_factor: float, e_start, E_loc: int):
    """Shared MoE math on a local token shard against a local expert range
    ``[e_start, e_start + E_loc)``.

    Sort-based slot assignment over the *global* expert ids (so capacity
    semantics match the single-device oracle), then only this shard's
    experts are gathered/computed. Returns (partial_out (N,D) f32, aux).
    """
    N, D = xf.shape
    E, K = cfg.num_experts, cfg.top_k
    logits = matmul(xf, router.astype(dt(cfg)))                    # (N,E) f32
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, K)                            # (N,K)
    gate = gate / jnp.sum(gate, -1, keepdims=True)

    # capacity: cf-scaled expected load; tiny batches (decode) get
    # drop-free capacity so teacher-forcing and decode agree exactly.
    C = max(1, int(math.ceil(N * K / E * capacity_factor)))
    C = max(C, min(64, N * K))
    flat_e = eidx.reshape(-1)                                        # (NK,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                          # (E,)
    starts = jnp.cumsum(counts) - counts
    slot = jnp.arange(N * K) - starts[sorted_e]                      # (NK,)
    local_e = sorted_e - e_start
    keep = (slot < C) & (local_e >= 0) & (local_e < E_loc)
    dest = jnp.where(keep, local_e * C + slot, E_loc * C)            # drop row
    tok = order // K

    buf = jnp.zeros((E_loc * C + 1, D), dt(cfg)).at[dest].set(xf[tok])
    xe = buf[: E_loc * C].reshape(E_loc, C, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg,
                               preferred_element_type=F32))
    h = h * jnp.einsum("ecd,edf->ecf", xe, wu, preferred_element_type=F32)
    ye = jnp.einsum("ecf,efd->ecd", h.astype(dt(cfg)), wd,
                    preferred_element_type=F32).astype(dt(cfg))

    yf = ye.reshape(E_loc * C, D)
    gate_sorted = gate.reshape(-1)[order]
    contrib = jnp.where(keep, gate_sorted, 0.0)[:, None]
    safe_dest = jnp.minimum(dest, E_loc * C - 1)
    out = jnp.zeros((N, D), F32).at[tok].add(yf[safe_dest] * contrib)
    # router aux loss (load-balancing, Switch-style) over local tokens
    me = jnp.mean(probs, 0)
    ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=F32), 0)
    aux = E * jnp.sum(me * ce)
    return out, aux


def apply_moe(params, x, cfg: ModelConfig, *, capacity_factor: float = 1.25):
    """Top-k routed MoE with fixed expert capacity (token-dropping).

    Distribution (DESIGN.md §5): under a mesh, a ``shard_map`` keeps tokens
    dp-local and experts tp-local — each device builds only *its* experts'
    (E_loc, C, D) queues from its (tp-replicated) token shard and the
    partial outputs are psum'd over tp. No (N·K, D) global gather ever
    exists (the naive pjit lowering replicated it: 114 GB/device on
    kimi-k2 train_4k — §Perf iteration 3). FSDP-sharded expert weights are
    all-gathered over dp by the shard_map resharding, preserving the
    standard FSDP schedule.
    """
    from repro.sharding import DP_AXES, TP_AXIS, current_mesh

    B, S, D = x.shape
    mesh = current_mesh()
    use_spmd = (mesh is not None and TP_AXIS in mesh.axis_names
                and mesh.size > 1 and cfg.num_experts % mesh.shape[TP_AXIS] == 0)
    if not use_spmd:
        xf = x.reshape(B * S, D)
        out, aux = _moe_math(xf, params["router"], params["w_gate"],
                             params["w_up"], params["w_down"], cfg,
                             capacity_factor, 0, cfg.num_experts)
        return out.reshape(B, S, D).astype(dt(cfg)), aux

    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in DP_AXES if a in mesh.axis_names)
    tp = TP_AXIS
    E_loc = cfg.num_experts // mesh.shape[tp]
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)

    # Expert weights enter in their native (EP × FSDP) sharding and are
    # all-gathered over dp *inside* the shard_map — the gather's backward
    # is a reduce-scatter, so expert grads stay FSDP-sharded (passing
    # pre-gathered weights instead left 43 GB/device of dp-replicated
    # expert grads on kimi-k2 — §Perf iteration 4).
    @functools.partial(
        compat.shard_map, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(None, None),
                  P(tp, dp_spec, None), P(tp, dp_spec, None),
                  P(tp, None, dp_spec)),
        out_specs=(P(dp_spec, None, None), P()),
        check_replication=False,
    )
    def run(x_loc, router, wg, wu, wd):
        b, s, _ = x_loc.shape
        if dp:
            wg = jax.lax.all_gather(wg, dp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, dp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, dp, axis=2, tiled=True)
        e_start = jax.lax.axis_index(tp) * E_loc
        out, aux = _moe_math(x_loc.reshape(b * s, D), router, wg, wu, wd,
                             cfg, capacity_factor, e_start, E_loc)
        out = jax.lax.psum(out, tp)                    # combine expert shards
        aux = jax.lax.pmean(jax.lax.pmean(aux, tp), dp) if dp \
            else jax.lax.pmean(aux, tp)
        return out.reshape(b, s, D).astype(dt(cfg)), aux

    return run(x, params["router"], params["w_gate"], params["w_up"],
               params["w_down"])


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state-space duality, chunked scan)
# ---------------------------------------------------------------------------

def init_ssm(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    din, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 5)
    return {
        # fused in-projection: [z (din), x (din), B (N), C (N), dt (nh)]
        "w_in": _dense_init(ks[0], (D, 2 * din + 2 * N + nh), 1.0, pdt(cfg)),
        "w_out": _dense_init(ks[1], (din, D), 1.0, pdt(cfg)),
        "conv": _dense_init(ks[2], (cfg.ssm_conv, din + 2 * N), 1.0, pdt(cfg)),
        "A_log": jnp.zeros((nh,), F32),       # A = -exp(A_log) in (-1, 0)
        "D": jnp.ones((nh,), F32),
        "dt_bias": jnp.zeros((nh,), F32),
        "norm": jnp.ones((din,), F32),        # gated RMSNorm scale
    }


def _ssm_split(params, x, cfg: ModelConfig):
    din, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = matmul_c(x, params["w_in"], cfg)
    z, xs, Bc, Cc, dtp = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1
    )
    dtp = jax.nn.softplus(dtp + params["dt_bias"])      # (B,S,nh) > 0
    return z, xs, Bc, Cc, dtp


def _causal_conv(xbc, conv_w, cache=None):
    """Depthwise causal conv1d. xbc (B,S,ch); conv_w (K,ch).

    With ``cache`` (B,K-1,ch) performs streaming single-step conv (S==1),
    returning (out, new_cache).
    """
    K = conv_w.shape[0]
    if cache is None:
        pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
        out = sum(pad[:, i : i + xbc.shape[1]] * conv_w[i] for i in range(K))
        return jax.nn.silu(out), pad[:, -(K - 1) :] if K > 1 else None
    full = jnp.concatenate([cache, xbc], 1)             # (B,K,ch)
    out = jnp.einsum("bkc,kc->bc", full, conv_w)[:, None]
    return jax.nn.silu(out), full[:, 1:]


def ssd_chunked(xh, dtp, A, Bc, Cc, cfg: ModelConfig, h0=None):
    """Chunked SSD scan (Dao & Gu 2024, Alg. in §6 of that paper).

    xh  (B,S,nh,P)  per-head inputs
    dtp (B,S,nh)    positive timestep
    A   (nh,)       negative scalar per head
    Bc/Cc (B,S,N)   shared-across-heads input/output projections
    h0  (B,nh,N,P)  initial state (decode/chunk-carry), optional
    Returns (y (B,S,nh,P), h_last (B,nh,N,P)).
    """
    B, S, nh, P = xh.shape
    N = Bc.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    S0 = S
    if S % Q:
        # pad to a chunk multiple with dt=0 positions: exp(0)=1 decay and
        # dt·B·x = 0 input make padding exactly state-neutral.
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtp = jnp.pad(dtp, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    xb = xh.reshape(B, nc, Q, nh, P)
    dtb = dtp.reshape(B, nc, Q, nh).astype(F32)
    Bb = Bc.reshape(B, nc, Q, N).astype(F32)
    Cb = Cc.reshape(B, nc, Q, N).astype(F32)

    dA = dtb * A[None, None, None, :]                   # (B,nc,Q,nh) <= 0
    cums = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    seg = jnp.exp(
        cums[:, :, :, None, :] - cums[:, :, None, :, :]
    )                                                    # (B,nc,Qq,Qs,nh)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, 0.0)

    # intra-chunk (quadratic within chunk, runs on MXU)
    G = jnp.einsum("bcqn,bcsn->bcqs", Cb, Bb, preferred_element_type=F32)
    M = G[:, :, :, :, None] * seg * dtb[:, :, None, :, :]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", M, xb.astype(F32),
                         preferred_element_type=F32)

    # per-chunk input->state contribution
    decay_suf = jnp.exp(cums[:, :, -1:, :] - cums)      # (B,nc,Q,nh)
    dx = xb.astype(F32) * dtb[..., None]                # (B,nc,Q,nh,P)
    chunk_state = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bb, decay_suf, dx,
                             preferred_element_type=F32)
    chunk_decay = jnp.exp(cums[:, :, -1, :])            # (B,nc,nh)

    def scan_fn(h, inp):
        cs, cd = inp                                     # (B,nh,N,P), (B,nh)
        h_out = h                                        # state entering chunk
        h = h * cd[:, :, None, None] + cs
        return h, h_out

    h_init = jnp.zeros((B, nh, N, P), F32) if h0 is None else h0.astype(F32)
    h_last, h_enter = jax.lax.scan(
        scan_fn,
        h_init,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_enter = jnp.moveaxis(h_enter, 0, 1)               # (B,nc,nh,N,P)

    # inter-chunk: y += C_t · (decay_prefix_t · h_enter)
    decay_pre = jnp.exp(cums)                            # (B,nc,Q,nh)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cb, decay_pre, h_enter,
                         preferred_element_type=F32)
    y = (y_intra + y_inter).reshape(B, S, nh, P)[:, :S0]
    return y, h_last


def ssm_block(params, x, cfg: ModelConfig, state=None, conv_cache=None):
    """Full mamba2 block. x (B,S,D). state/conv_cache for streaming decode.

    Returns (out (B,S,D), new_state, new_conv_cache).
    """
    B, S, D = x.shape
    din, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    z, xs, Bc, Cc, dtp = _ssm_split(params, x, cfg)
    xbc = jnp.concatenate([xs, Bc, Cc], -1).astype(dt(cfg))
    conv_out, new_conv = _causal_conv(xbc, params["conv"].astype(dt(cfg)), conv_cache)
    xs, Bc, Cc = jnp.split(conv_out, [din, din + N], axis=-1)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B, S, nh, P)
    if S == 1 and state is not None:
        # streaming decode: h' = exp(A dt) h + dt B x ; y = C h
        dtp1 = dtp[:, 0].astype(F32)                      # (B,nh)
        da = jnp.exp(dtp1 * A[None, :])
        bx = jnp.einsum("bn,bhp->bhnp", Bc[:, 0].astype(F32),
                        xh[:, 0].astype(F32) * dtp1[..., None])
        h = state * da[:, :, None, None] + bx
        y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(F32), h)[:, None]
        new_state = h
    else:
        y, new_state = ssd_chunked(xh, dtp, A, Bc, Cc, cfg, h0=state)
    y = y + params["D"][None, None, :, None] * xh.astype(F32)
    y = y.reshape(B, S, din)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z.astype(F32))
    y = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + cfg.norm_eps)
    y = (y * params["norm"]).astype(dt(cfg))
    out = matmul_rp(y.astype(dt(cfg)), params["w_out"], cfg).astype(dt(cfg))
    return out, new_state, new_conv
