"""Checkpointing: atomic, sharded-array-safe, elastic-restore.

Design (DESIGN.md §6):
  * Arrays are saved *logically* (fully gathered to host) so a restart may
    use a different mesh shape — resharding happens at load-time
    ``device_put`` by the caller. This is what makes 512→448-chip degraded
    restarts work.
  * Atomicity: write to ``<step>.tmp-<pid>`` then ``os.replace`` — a
    killed writer never corrupts the latest checkpoint.
  * ``latest`` is a one-line pointer file, also atomically replaced.
  * Retention: keep the newest ``keep`` checkpoints.
  * Restore takes the template pytree (from init) and fills leaves by
    flattened key-path, so optimizer/param tree evolution fails loudly
    instead of silently misloading.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3):
    """Atomically persist ``tree`` at ``step``. Returns the file path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    for i, (path, leaf) in enumerate(flat):
        arrays[f"a{i}"] = np.asarray(jax.device_get(leaf))
    names = [_keystr(p) for p, _ in flat]
    final = os.path.join(ckpt_dir, f"ckpt_{step:010d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, __names__=np.array(json.dumps(names)),
                 __step__=np.int64(step), **arrays)
    os.replace(tmp, final)
    # atomic latest pointer
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        f.write(os.path.basename(final))
    os.replace(tmp, os.path.join(ckpt_dir, "latest"))
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    ckpts = sorted(f for f in os.listdir(ckpt_dir)
                   if f.startswith("ckpt_") and f.endswith(".npz"))
    for f in ckpts[:-keep] if keep > 0 else []:
        try:
            os.remove(os.path.join(ckpt_dir, f))
        except OSError:
            pass


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(ckpt_dir, name)
    if not os.path.exists(path):
        return None
    return int(name.split("_")[1].split(".")[0])


def restore_checkpoint(ckpt_dir: str, template: Any,
                       step: Optional[int] = None) -> Tuple[int, Any]:
    """Restore into the structure of ``template``. Returns (step, tree).

    Loaded leaves stay on host (numpy); callers ``device_put`` with their
    (possibly different) target sharding — elastic restore.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"ckpt_{step:010d}.npz")
    with np.load(path, allow_pickle=False) as z:
        names = json.loads(str(z["__names__"]))
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        t_names = [_keystr(p) for p, _ in flat_t]
        if names != t_names:
            missing = set(t_names) - set(names)
            extra = set(names) - set(t_names)
            raise ValueError(
                f"checkpoint/template structure mismatch: missing={sorted(missing)[:5]} "
                f"extra={sorted(extra)[:5]}")
        leaves = [z[f"a{i}"] for i in range(len(names))]
    return step, jax.tree_util.tree_unflatten(treedef, leaves)
