from .pipeline import host_slice, model_batch, token_batch  # noqa: F401
from .pointsets import GENERATORS, gau, kddlike, pokerlike, unb, unif  # noqa: F401
