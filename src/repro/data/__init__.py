from .pipeline import host_slice, model_batch, token_batch  # noqa: F401
from .pointsets import GENERATORS, gau, kddlike, pokerlike, unb, unif  # noqa: F401
from .source import (  # noqa: F401
    ArraySource,
    HostSource,
    IndexedSource,
    MemmapSource,
    PointSource,
    ProcessShardedSource,
    RemoteShard,
    ShardedSource,
    SliceSource,
    SyntheticSource,
    WeightedSource,
    as_device_array,
    as_source,
    has_weights,
    is_source,
    shard_source,
    synthetic_source,
    take_weights,
    weights_of,
)
