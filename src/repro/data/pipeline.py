"""Deterministic, resumable synthetic token pipeline.

Every batch is a pure function of (seed, step) via counter-based Philox
keys — restart-exactness for the fault-tolerance path (DESIGN.md §6):
resuming from a checkpoint at step s replays batch s identically, with no
stream state to persist.

The synthetic language is a noisy affine bigram chain
``x[t+1] = (a·x[t] + b) mod V`` with p=0.2 uniform noise — enough learnable
structure that training-loss decrease is a meaningful integration test.

For multi-host data loading each host materializes only its shard
(``host_slice``): batches are generated shard-locally from the same
(seed, step), so no host reads another host's slice — the standard
per-host data-loading pattern at pod scale.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.models.config import ModelConfig


def _rng(seed: int, step: int) -> np.random.Generator:
    # Philox key is 2×64-bit: (salted seed, step) — counter-based, so a
    # batch is a pure function of (seed, step).
    key = np.array([(seed ^ 0x5EED_DA7A) & 0xFFFFFFFFFFFFFFFF, step],
                   dtype=np.uint64)
    return np.random.Generator(np.random.Philox(key=key))


def token_batch(vocab: int, batch: int, seq: int, *, seed: int = 0,
                step: int = 0, noise: float = 0.2) -> Dict[str, np.ndarray]:
    """(tokens, labels) of shape (batch, seq); labels are next-tokens."""
    r = _rng(seed, step)
    a = 31337 % vocab or 1
    b = 17
    x0 = r.integers(0, vocab, (batch, 1))
    cols = [x0]
    for _ in range(seq):
        nxt = (cols[-1] * a + b) % vocab
        flip = r.random((batch, 1)) < noise
        rnd = r.integers(0, vocab, (batch, 1))
        cols.append(np.where(flip, rnd, nxt))
    stream = np.concatenate(cols, axis=1)
    return {"tokens": stream[:, :seq].astype(np.int32),
            "labels": stream[:, 1 : seq + 1].astype(np.int32)}


def model_batch(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0,
                step: int = 0) -> Dict[str, np.ndarray]:
    """Full input dict for any family (frames/patches stubs included)."""
    out = token_batch(cfg.vocab_size, batch, seq, seed=seed, step=step)
    r = _rng(seed ^ 0xF00D, step)
    if cfg.family == "encdec":
        out["frames"] = r.normal(0, 1, (batch, cfg.enc_seq, cfg.d_model)
                                 ).astype(np.float32)
    if cfg.family == "vlm":
        out["patch_embeds"] = r.normal(
            0, 1, (batch, cfg.num_patches, cfg.d_model)).astype(np.float32)
    return out


def host_slice(batch_dict: Dict[str, np.ndarray], host_id: int,
               num_hosts: int) -> Dict[str, np.ndarray]:
    """This host's slice of the global batch (leading-axis shard)."""
    def sl(x):
        per = x.shape[0] // num_hosts
        return x[host_id * per : (host_id + 1) * per]
    return {k: sl(v) for k, v in batch_dict.items()}
