"""Point-set generators for the paper's experiment families (§7.3).

UNIF — uniform in a 2-D square (side 100, matching the paper's value
scale, e.g. Table 3's radii ~91 at k=2).
GAU  — k' cluster centers uniform in a cube of side 100; points assigned
uniformly to clusters; Gaussian offset with σ = 1/10 (the paper's σ; the
tight σ is why GAU radii collapse from ~40 to ~1 once k >= k').
UNB  — like GAU but ~half of all points in one cluster.

All generators are counter-based (Philox) — fully deterministic in
(seed, size), independent of call order; the paper generates 3 graphs per
(type, size) and averages over repeated runs, which benchmarks mirror.
"""
from __future__ import annotations

import numpy as np


def _rng(seed: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=np.uint64(seed)))


def unif(n: int, d: int = 2, *, seed: int = 0, side: float = 100.0):
    return (_rng(seed).random((n, d)) * side).astype(np.float32)


def gau(n: int, k_prime: int = 25, d: int = 2, *, seed: int = 0,
        side: float = 100.0, sigma: float = 0.1, centers=None):
    """``centers`` (k', d) overrides the drawn cluster centers — used by
    ``data/source.synthetic_source`` so every block shares one set."""
    r = _rng(seed)
    if centers is None:
        centers = r.random((k_prime, d)) * side
    assign = r.integers(0, k_prime, n)
    pts = centers[assign] + r.normal(0.0, sigma, (n, d))
    return pts.astype(np.float32)


def unb(n: int, k_prime: int = 25, d: int = 2, *, seed: int = 0,
        side: float = 100.0, sigma: float = 0.1, big_frac: float = 0.5,
        centers=None):
    """See ``gau`` for the ``centers`` override."""
    r = _rng(seed)
    if centers is None:
        centers = r.random((k_prime, d)) * side
    n_big = int(n * big_frac)
    assign = np.concatenate([
        np.zeros(n_big, np.int64),
        r.integers(1, k_prime, n - n_big),
    ])
    pts = centers[assign] + r.normal(0.0, sigma, (n, d))
    return pts.astype(np.float32)


def kddlike(n: int, d: int = 38, *, seed: int = 0):
    """High-dimensional heavy-tailed proxy for the KDD CUP 1999 sample
    (UCI data unavailable offline; DESIGN.md §9)."""
    r = _rng(seed)
    base = r.lognormal(0.0, 1.5, (n, d))
    mask = r.random((n, d)) < 0.7          # many near-zero features
    return (base * mask).astype(np.float32)


def pokerlike(n: int, *, seed: int = 0):
    """Integer-grid proxy for the POKER HAND set (10 categorical-ish dims)."""
    r = _rng(seed)
    suits = r.integers(1, 5, (n, 5)).astype(np.float32)
    ranks = r.integers(1, 14, (n, 5)).astype(np.float32)
    return np.concatenate([suits, ranks], axis=1)


GENERATORS = {"unif": unif, "gau": gau, "unb": unb, "kddlike": kddlike,
              "pokerlike": pokerlike}
