"""Out-of-core point sources — the input side of the paper's machine model.

The paper's MapReduce formulation (§3) never assumes the input is one
resident array: points live partitioned across machines of capacity ``c``.
A ``PointSource`` makes that explicit in the framework: it decouples *where
points live* (device HBM, host RAM, on-disk shards, or a generator program)
from *how algorithms consume them* (whole-array ops, or block streams
bounded by a memory budget). Executors (``repro.core.executor``) impose the
machine blocking on top; the chunked distance engine
(``repro.kernels.engine``) bounds each block's per-pass working set below
that.

Sources:

  * ``ArraySource``   — a device-resident array; today's behavior, zero-copy.
  * ``HostSource``    — host-resident numpy, streamed block-by-block with
                        double-buffered ``jax.device_put`` (the DMA of block
                        i+1 is enqueued before block i is yielded), so n is
                        bounded by host RAM instead of HBM.
  * ``MemmapSource``  — one or more on-disk ``.npy`` shards opened with
                        ``mmap_mode="r"``; n is bounded by disk. Blocks are
                        *global* row ranges (shard boundaries are invisible
                        to consumers, so blocking is independent of how the
                        data was sharded on disk).
  * ``SyntheticSource`` — a counter-based generator program; blocks are
                        materialized on demand, so benchmarks at n = 10⁷
                        never hold the full set even on the host. Built from
                        the ``data/pointsets.py`` families via
                        ``synthetic_source``.
  * ``SliceSource``   — a contiguous-row view ``[start, stop)`` of any
                        source with ``take``; three integers of state, so
                        splitting an n-row source costs O(1).
  * ``ShardedSource`` — one source per machine shard (the paper's "input
                        already partitioned across machines"); built by
                        ``shard_source(source, mesh)`` (zero-copy
                        ``SliceSource`` split) or
                        ``ShardedSource.from_per_host_shards`` for
                        genuinely distributed inputs. ``MeshExecutor``
                        streams each shard into its own mesh address
                        space, so no host ever holds all n rows.
  * ``WeightedSource`` — per-row f32 weights attached to any source (the
                        weighted instances of Ceccarello et al.
                        1802.09205: coreset points carrying cluster
                        sizes). Weights ride the same blocking as the
                        points — ``weights_of(start, rows)`` returns the
                        block's weight slice — and every *unweighted*
                        source gets the default-ones path through the
                        module-level ``weights_of``/``take_weights``
                        helpers, so weighted folds run on any source.
                        Views compose: an ``IndexedSource``/
                        ``SliceSource``/``ShardedSource`` over a weighted
                        parent serves its rows' weights through the view.

``blocks(block_rows)`` yields float32 device arrays of shape
``(<= block_rows, d)`` covering rows ``[0, n)`` in order; it may be called
any number of times (each call restarts the stream — memmaps re-read,
generators regenerate deterministically). Host-backed sources upload
through a small device-side *prefetch ring* (``prefetch=2`` by default):
up to ``prefetch`` blocks' DMAs are in flight ahead of the consumed one,
so at the peak ``1 + prefetch`` blocks are device-resident — the engine's
``resolve_block_rows`` residency model ``(1+prefetch)·4·rows·(d+1)``
accounts for all of them. ``prefetch=1`` recovers the old double buffer.
Host-backed sources also expose ``host_blocks(block_rows)`` yielding numpy
blocks with no device transfer at all, for consumers whose fold runs on
the host (e.g. the streaming doubling sketch). Every built-in source
provides ``row(idx)`` — host-side random access to one row (the streamed
GON's first-center fetch) — and ``take(indices)`` — a host-side gather of
arbitrary rows (Memmap/Host index cheaply; Synthetic regenerates the
containing runs), which is how the streamed EIM compacts its sample ("send
C to one machine", paper §4 final round) without ever uploading all of n.

Determinism: ``synthetic_source("unif", ...)`` reproduces ``pointsets.unif``
*bitwise* for any blocking (the Philox counter is advanced to the block's
stream offset). The ``gau``/``unb`` families share one set of cluster
centers across blocks (drawn exactly as the monolithic generator draws
them) but use per-block child seeds for assignments and noise, so they are
distribution-identical, not bitwise-identical, to the monolithic call.
"""
from __future__ import annotations

import os
from collections import deque
from typing import Callable, Iterator, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

# The single home of the ring-depth default is the engine's residency
# model (kernels/engine.py imports nothing from repro.data, so this
# direction is cycle-free).
from repro import compat
from repro.kernels.engine import DEFAULT_PREFETCH  # noqa: F401

from . import pointsets


@runtime_checkable
class PointSource(Protocol):
    """Anything with ``n``, ``d`` and restartable block iteration."""

    @property
    def n(self) -> int: ...

    @property
    def d(self) -> int: ...

    def blocks(self, block_rows: int) -> Iterator[jnp.ndarray]: ...


def is_source(x) -> bool:
    """Duck-typed source check, safe on jax tracers and numpy arrays."""
    return hasattr(x, "blocks") and hasattr(x, "n") and hasattr(x, "d")


def as_source(x) -> "PointSource":
    """Coerce to a PointSource: sources pass through, host numpy becomes a
    ``HostSource``, anything array-like becomes a device ``ArraySource``."""
    if is_source(x):
        return x
    if isinstance(x, np.ndarray):
        return HostSource(x)
    return ArraySource(x)


def as_device_array(x) -> jnp.ndarray:
    """Materialize a source (or pass an array through) as a float32 device
    array — for algorithms that need random access (e.g. EIM's masks)."""
    if is_source(x):
        # reprolint: disable=R002 -- documented random-access escape hatch; callers budget for full residency (EIM masks)
        return x.materialize()
    return jnp.asarray(x, jnp.float32)


def _check_rows(block_rows: int) -> int:
    if block_rows < 1:
        raise ValueError(f"block_rows must be >= 1, got {block_rows}")
    return int(block_rows)


def has_weights(source) -> bool:
    """True iff ``source`` carries per-row weights (a ``WeightedSource`` or
    a view over one). Duck-typed so the check is safe on any source."""
    return bool(getattr(source, "has_weights", False))


def weights_of(source, start: int, rows: int) -> np.ndarray:
    """f32 weights of rows ``[start, start + rows)`` of ``source``.

    This is *the* default-ones path: unweighted sources (no ``weights_of``
    method) get ``np.ones(rows)``, so every weighted fold runs unchanged on
    every existing source — with unit weights it computes the plain
    objective bit-for-bit (the masks it builds from ``w > 0`` are the
    all-True masks of the unweighted program)."""
    fn = getattr(source, "weights_of", None)
    if fn is None:
        return np.ones((int(rows),), np.float32)
    w = np.asarray(fn(start, rows), np.float32).reshape(-1)
    if w.shape[0] != rows:
        raise ValueError(
            f"weights_of({start}, {rows}) returned {w.shape[0]} weights")
    return w


def take_weights(source, indices) -> np.ndarray:
    """f32 weights of the gathered rows ``indices`` (ones when unweighted
    — the gather-side sibling of ``weights_of``)."""
    fn = getattr(source, "take_weights", None)
    idx = np.asarray(indices, np.int64).reshape(-1)
    if fn is None:
        return np.ones((idx.size,), np.float32)
    w = np.asarray(fn(idx), np.float32).reshape(-1)
    if w.shape[0] != idx.size:
        raise ValueError(
            f"take_weights returned {w.shape[0]} weights for "
            f"{idx.size} indices")
    return w


def stream_device(host_blocks: Iterator[np.ndarray],
                  prefetch: int = DEFAULT_PREFETCH,
                  put: Callable | None = None) -> Iterator:
    """Ring-buffered host→device upload: keep up to ``prefetch`` blocks'
    transfers in flight ahead of the consumed one (``device_put`` is
    asynchronous), so DMA overlaps the consumer's compute across several
    blocks of lookahead. At the moment a block is yielded, it plus the
    ``prefetch`` ring slots are device-resident — the ``(1+prefetch)``
    residency model of ``engine.resolve_block_rows``. ``prefetch=1`` is
    the classic double buffer.

    ``put`` customizes the transfer (default ``jax.device_put``): the
    sharded executors pass a closure that device-puts each shard's piece
    into its own mesh address space (``compat.global_array_from_shards``),
    so the same ring drives single-device and mesh-sharded streaming.
    """
    if prefetch < 1:
        raise ValueError(f"prefetch must be >= 1, got {prefetch}")
    if put is None:
        put = jax.device_put
    it = iter(host_blocks)
    ring: deque = deque()

    def fill() -> None:
        while len(ring) < prefetch:
            try:
                ring.append(put(next(it)))
            except StopIteration:
                return

    fill()
    while ring:
        cur = ring.popleft()
        fill()          # top the ring back up before handing over control
        yield cur


# Historical (pre-sharding) name, kept for callers of the private form.
_stream_device = stream_device


def _check_take_indices(indices, n: int) -> np.ndarray:
    idx = np.asarray(indices, np.int64).reshape(-1)
    if idx.size and (idx.min() < 0 or idx.max() >= n):
        raise IndexError(
            f"take indices out of range [0, {n}): "
            f"min={idx.min()}, max={idx.max()}")
    return idx


class ArraySource:
    """Device-resident ``(n, d)`` array — the legacy in-memory input."""

    def __init__(self, array):
        self._x = jnp.asarray(array, jnp.float32)
        if self._x.ndim != 2:
            raise ValueError(f"expected (n, d) points, got shape {self._x.shape}")

    @property
    def n(self) -> int:
        return self._x.shape[0]

    @property
    def d(self) -> int:
        return self._x.shape[1]

    def blocks(self, block_rows: int, *,
               prefetch: int = DEFAULT_PREFETCH) -> Iterator[jnp.ndarray]:
        del prefetch  # already device-resident: slicing is zero-copy
        rows = _check_rows(block_rows)
        for start in range(0, self.n, rows):
            yield self._x[start:start + rows]

    def row(self, idx: int) -> np.ndarray:
        return np.asarray(self._x[idx])

    def take(self, indices) -> np.ndarray:
        """Gather rows ``indices`` (host numpy result, device-side gather)."""
        idx = _check_take_indices(indices, self.n)
        return np.asarray(jnp.take(self._x, jnp.asarray(idx, jnp.int32),
                                   axis=0))

    def materialize(self) -> jnp.ndarray:
        return self._x


class HostSource:
    """Host-resident numpy points streamed to the device block-by-block."""

    def __init__(self, array: np.ndarray):
        self._x = np.asarray(array, np.float32)
        if self._x.ndim != 2:
            raise ValueError(f"expected (n, d) points, got shape {self._x.shape}")

    @property
    def n(self) -> int:
        return self._x.shape[0]

    @property
    def d(self) -> int:
        return self._x.shape[1]

    def host_blocks(self, block_rows: int) -> Iterator[np.ndarray]:
        """Numpy blocks with no device transfer (host-side folds)."""
        rows = _check_rows(block_rows)
        for start in range(0, self.n, rows):
            yield self._x[start:start + rows]

    def blocks(self, block_rows: int, *,
               prefetch: int = DEFAULT_PREFETCH) -> Iterator[jnp.ndarray]:
        return _stream_device(self.host_blocks(block_rows), prefetch)

    def row(self, idx: int) -> np.ndarray:
        return self._x[idx]

    def take(self, indices) -> np.ndarray:
        """Gather rows ``indices`` — a plain numpy fancy index."""
        return self._x[_check_take_indices(indices, self.n)]

    def materialize(self) -> jnp.ndarray:
        return jnp.asarray(self._x)


class MemmapSource:
    """On-disk ``.npy`` shards, memory-mapped; n is bounded by disk.

    ``paths`` is one path or an ordered sequence of shard paths; shards are
    logically concatenated along rows. Blocks are global row ranges, so a
    block may span a shard boundary (the pieces are concatenated on the
    host before the device upload).
    """

    def __init__(self, paths: str | os.PathLike | Sequence[str | os.PathLike]):
        if isinstance(paths, (str, os.PathLike)):
            paths = [paths]
        if not paths:
            raise ValueError("MemmapSource needs at least one shard path")
        self._paths = [str(p) for p in paths]
        self._maps = [np.load(p, mmap_mode="r") for p in self._paths]
        d = self._maps[0].shape[1]
        for p, m in zip(self._paths, self._maps):
            if m.ndim != 2 or m.shape[1] != d:
                raise ValueError(
                    f"shard {p} has shape {m.shape}, expected (rows, {d})")
        self._offsets = np.cumsum([0] + [m.shape[0] for m in self._maps])

    @property
    def n(self) -> int:
        return int(self._offsets[-1])

    @property
    def d(self) -> int:
        return int(self._maps[0].shape[1])

    def _slice(self, start: int, stop: int) -> np.ndarray:
        """Rows ``[start, stop)`` of the logical concatenation, as f32.

        Only the shards overlapping the range are touched — located by
        ``np.searchsorted`` on the cumulative ``_offsets`` (the same index
        ``take`` uses), so a block stream costs O(blocks + shards) shard
        visits total instead of O(blocks · shards)."""
        if stop <= start:
            return np.zeros((0, self.d), np.float32)
        first = int(np.searchsorted(self._offsets, start, side="right")) - 1
        last = int(np.searchsorted(self._offsets, stop, side="left"))
        pieces = []
        for s in range(max(first, 0), last):
            off = int(self._offsets[s])
            m = self._maps[s]
            lo = max(start - off, 0)
            hi = min(stop - off, m.shape[0])
            if lo < hi:
                pieces.append(np.asarray(m[lo:hi], np.float32))
        if len(pieces) == 1:
            return pieces[0]
        return np.concatenate(pieces, axis=0)

    def host_blocks(self, block_rows: int) -> Iterator[np.ndarray]:
        """Numpy blocks with no device transfer (host-side folds)."""
        rows = _check_rows(block_rows)
        for start in range(0, self.n, rows):
            yield self._slice(start, min(start + rows, self.n))

    @property
    def num_shards(self) -> int:
        return len(self._paths)

    def blocks(self, block_rows: int, *,
               prefetch: int = DEFAULT_PREFETCH) -> Iterator[jnp.ndarray]:
        return _stream_device(self.host_blocks(block_rows), prefetch)

    def row(self, idx: int) -> np.ndarray:
        return self._slice(idx, idx + 1)[0]

    def take(self, indices) -> np.ndarray:
        """Gather rows ``indices`` across shards — each shard is fancy-
        indexed once with its share of the (order-preserved) indices, so
        the cost is O(|indices|) reads, never a shard scan."""
        idx = _check_take_indices(indices, self.n)
        out = np.empty((idx.size, self.d), np.float32)
        shard = np.searchsorted(self._offsets, idx, side="right") - 1
        for s in np.unique(shard):
            sel = shard == s
            out[sel] = np.asarray(
                self._maps[s][idx[sel] - self._offsets[s]], np.float32)
        return out

    def materialize(self) -> jnp.ndarray:
        return jnp.asarray(self._slice(0, self.n))

    @classmethod
    def save_shards(cls, array: np.ndarray, dirpath: str | os.PathLike, *,
                    rows_per_shard: int) -> "MemmapSource":
        """Write ``array`` as numbered ``.npy`` shards under ``dirpath``."""
        rows_per_shard = _check_rows(rows_per_shard)
        array = np.asarray(array, np.float32)
        os.makedirs(dirpath, exist_ok=True)
        paths = []
        for i, start in enumerate(range(0, array.shape[0], rows_per_shard)):
            p = os.path.join(str(dirpath), f"shard_{i:05d}.npy")
            np.save(p, array[start:start + rows_per_shard])
            paths.append(p)
        return cls(paths)


class SyntheticSource:
    """Blocks computed on demand by ``block_fn(start, rows) -> (rows, d)``.

    The full (n, d) set is never materialized anywhere — each block is
    generated on the host and DMA'd like a ``HostSource`` block. ``block_fn``
    must be deterministic in ``(start, rows)`` so the stream can restart.
    """

    def __init__(self, block_fn: Callable[[int, int], np.ndarray], n: int,
                 d: int | None = None, *, name: str = "synthetic"):
        self._fn = block_fn
        self._n = int(n)
        if d is None:
            d = int(np.asarray(block_fn(0, 1)).shape[1])
        self._d = int(d)
        self.name = name

    @property
    def n(self) -> int:
        return self._n

    @property
    def d(self) -> int:
        return self._d

    def host_blocks(self, block_rows: int) -> Iterator[np.ndarray]:
        """Numpy blocks with no device transfer (host-side folds)."""
        rows = _check_rows(block_rows)
        for start in range(0, self._n, rows):
            blk = np.asarray(self._fn(start, min(rows, self._n - start)),
                             np.float32)
            yield blk

    def blocks(self, block_rows: int, *,
               prefetch: int = DEFAULT_PREFETCH) -> Iterator[jnp.ndarray]:
        return _stream_device(self.host_blocks(block_rows), prefetch)

    def row(self, idx: int) -> np.ndarray:
        return np.asarray(self._fn(idx, 1), np.float32)[0]

    def take(self, indices) -> np.ndarray:
        """Gather rows ``indices`` by regeneration: each maximal run of
        consecutive indices costs one ``block_fn`` call (EIM's sampled
        index sets arrive sorted, so runs are common)."""
        idx = _check_take_indices(indices, self.n)
        out = np.empty((idx.size, self._d), np.float32)
        i = 0
        while i < idx.size:
            j = i + 1
            while j < idx.size and idx[j] == idx[j - 1] + 1:
                j += 1
            out[i:j] = np.asarray(self._fn(int(idx[i]), int(j - i)),
                                  np.float32)
            i = j
        return out

    def materialize(self) -> jnp.ndarray:
        return jnp.concatenate(
            [jnp.asarray(b) for b in self.host_blocks(1 << 20)], axis=0)


class IndexedSource:
    """A view of a parent source through a sorted global-row index array.

    This is how the compacted-R streamed EIM makes a shrunken relation a
    first-class ``PointSource``: view-row ``j`` is parent-row
    ``indices[j]``, so a fold over the view touches only the surviving
    rows while every per-row identity (the Philox counter the Round-1
    sampler keys on) stays the *parent's* absolute index.

    ``indices`` must be strictly increasing (sorted, duplicate-free) — the
    view preserves global row order, which is what keeps cross-block value
    folds (min / top-k) bitwise identical to the uncompacted pass, and
    what lets ``take`` exploit maximal consecutive runs in the parent
    (``SyntheticSource.take`` regenerates one run per ``block_fn`` call;
    ``MemmapSource.take`` fancy-indexes each shard once).

    Nested views compose: ``IndexedSource(IndexedSource(p, a), b)``
    re-points at ``p`` through ``a[b]``, so chained compactions never
    stack gather layers.
    """

    def __init__(self, parent, indices):
        idx = np.asarray(indices, np.int64).reshape(-1)
        if idx.size:
            if idx[0] < 0 or idx[-1] >= parent.n:
                # (idx is checked sorted below, so min/max are the ends —
                # but report honest bounds even for unsorted input)
                raise IndexError(
                    f"view indices out of range [0, {parent.n}): "
                    f"min={idx.min()}, max={idx.max()}")
            if idx.size > 1 and (np.diff(idx) <= 0).any():
                raise ValueError(
                    "IndexedSource indices must be strictly increasing "
                    "(sorted, no duplicates) — the view preserves global "
                    "row order")
        if isinstance(parent, IndexedSource):
            idx = parent._idx[idx]
            parent = parent._parent
        self._parent = parent
        self._idx = idx

    @property
    def parent(self):
        return self._parent

    @property
    def indices(self) -> np.ndarray:
        """The (root-composed) global row indices this view selects."""
        return self._idx

    @property
    def n(self) -> int:
        return int(self._idx.size)

    @property
    def d(self) -> int:
        return self._parent.d

    def host_blocks(self, block_rows: int) -> Iterator[np.ndarray]:
        """Numpy blocks gathered from the parent (``take`` exploits
        maximal runs), no device transfer."""
        rows = _check_rows(block_rows)
        for start in range(0, self.n, rows):
            yield self._parent.take(self._idx[start:start + rows])

    def blocks(self, block_rows: int, *,
               prefetch: int = DEFAULT_PREFETCH) -> Iterator[jnp.ndarray]:
        return _stream_device(self.host_blocks(block_rows), prefetch)

    def row(self, idx: int) -> np.ndarray:
        if not 0 <= idx < self.n:
            raise IndexError(f"row {idx} out of range for n={self.n}")
        return self._parent.row(int(self._idx[idx]))

    def take(self, indices) -> np.ndarray:
        """Gather view rows — composes through to the parent's indices."""
        idx = _check_take_indices(indices, self.n)
        return self._parent.take(self._idx[idx])

    @property
    def has_weights(self) -> bool:
        return has_weights(self._parent)

    def weights_of(self, start: int, rows: int) -> np.ndarray:
        stop = min(start + rows, self.n)
        return take_weights(self._parent, self._idx[start:stop])

    def take_weights(self, indices) -> np.ndarray:
        idx = _check_take_indices(indices, self.n)
        return take_weights(self._parent, self._idx[idx])

    def materialize(self) -> jnp.ndarray:
        return jnp.asarray(self._parent.take(self._idx))


class SliceSource:
    """Contiguous-row view ``[start, stop)`` of a parent source.

    The machine-shard sibling of ``IndexedSource``: where a view through an
    index array carries O(|view|) state, a slice view is three integers —
    which is what lets ``shard_source`` split an n-row source into
    per-machine shards without any host ever holding an O(n) structure
    (index arrays included). Blocks are gathered through the parent's
    ``take``; every built-in source serves a maximal consecutive run
    cheaply (``MemmapSource`` fancy-indexes only the overlapping disk
    shards, ``SyntheticSource`` regenerates the run with one ``block_fn``
    call), so streaming a shard costs O(block_rows) working memory.

    Nested slices compose: ``SliceSource(SliceSource(p, a, b), c, d)``
    re-points directly at ``p`` through ``[a + c, a + d)``.
    """

    def __init__(self, parent, start: int, stop: int):
        start, stop = int(start), int(stop)
        if not hasattr(parent, "take"):
            raise TypeError(
                f"SliceSource needs a parent with take() for run gathers; "
                f"{type(parent).__name__} does not provide it")
        if not 0 <= start <= stop <= parent.n:
            raise ValueError(
                f"slice [{start}, {stop}) out of range for n={parent.n}")
        if isinstance(parent, SliceSource):
            start += parent._start
            stop += parent._start
            parent = parent._parent
        self._parent = parent
        self._start = start
        self._stop = stop

    @property
    def parent(self):
        return self._parent

    @property
    def start(self) -> int:
        """First (root-composed) parent row this view selects."""
        return self._start

    @property
    def stop(self) -> int:
        """One past the last parent row this view selects."""
        return self._stop

    @property
    def n(self) -> int:
        return self._stop - self._start

    @property
    def d(self) -> int:
        return self._parent.d

    def host_blocks(self, block_rows: int) -> Iterator[np.ndarray]:
        """Numpy blocks gathered from the parent run-by-run, no device
        transfer."""
        rows = _check_rows(block_rows)
        for a in range(self._start, self._stop, rows):
            yield self._parent.take(np.arange(a, min(a + rows, self._stop),
                                              dtype=np.int64))

    def blocks(self, block_rows: int, *,
               prefetch: int = DEFAULT_PREFETCH) -> Iterator[jnp.ndarray]:
        return stream_device(self.host_blocks(block_rows), prefetch)

    def row(self, idx: int) -> np.ndarray:
        if not 0 <= idx < self.n:
            raise IndexError(f"row {idx} out of range for n={self.n}")
        return self._parent.row(self._start + idx)

    def take(self, indices) -> np.ndarray:
        """Gather view rows — offsets through to the parent."""
        idx = _check_take_indices(indices, self.n)
        return self._parent.take(idx + self._start)

    @property
    def has_weights(self) -> bool:
        return has_weights(self._parent)

    def weights_of(self, start: int, rows: int) -> np.ndarray:
        stop = min(start + rows, self.n)
        return weights_of(self._parent, self._start + start,
                          max(stop - start, 0))

    def take_weights(self, indices) -> np.ndarray:
        idx = _check_take_indices(indices, self.n)
        return take_weights(self._parent, idx + self._start)

    def materialize(self) -> jnp.ndarray:
        return jnp.asarray(self._parent.take(
            np.arange(self._start, self._stop, dtype=np.int64)))


class ShardedSource:
    """One ``PointSource`` per machine shard — the paper's input model.

    The MapReduce formulation (§3) assumes the input is *already
    partitioned across machines*; Ene–Im–Moseley's model makes the same
    per-machine-memory assumption explicit. ``ShardedSource`` is that
    partition as a first-class object: shard ``s`` is its own
    ``PointSource`` (host numpy, a disk shard, a generator program, or a
    ``SliceSource`` view of a common parent) and the global row order is
    the concatenation of the shards in order. ``MeshExecutor`` streams
    each shard's blocks into that shard's mesh address space, so no host
    buffer ever holds all n rows — per-shard working memory is bounded by
    the executor's ``memory_budget``.

    Construct with ``shard_source(source, shards)`` to split one logical
    source into zero-copy contiguous views, or
    ``ShardedSource.from_per_host_shards([...])`` when the shards already
    exist separately (one file / array / generator per host).

    As a plain ``PointSource`` it behaves as the concatenation: ``blocks``
    streams shard after shard (a block never crosses a shard boundary, so
    each shard's tail block may be ragged — value folds are invariant to
    that; see ``kernels/engine.py``), ``take``/``row`` dispatch on the
    shard offsets, and ``materialize`` concatenates (a convenience for
    tests and small n — never used on the streamed paths).
    """

    def __init__(self, shards: Sequence):
        shards = list(shards)
        if not shards:
            raise ValueError("ShardedSource needs at least one shard")
        for i, s in enumerate(shards):
            if not is_source(s):
                raise TypeError(
                    f"shard {i} ({type(s).__name__}) is not a PointSource")
        d = shards[0].d
        for i, s in enumerate(shards):
            if s.d != d:
                raise ValueError(
                    f"shard {i} has d={s.d}, expected d={d} (all shards "
                    "must share one point dimension)")
        self._shards = tuple(shards)
        self._offsets = np.cumsum([0] + [s.n for s in shards])

    @classmethod
    def from_per_host_shards(cls, shards: Sequence) -> "ShardedSource":
        """Wrap genuinely distributed inputs: one pre-existing source per
        host/machine (e.g. each host's ``MemmapSource`` over its local
        ``.npy`` shards, or a per-host ``SyntheticSource``). Shard order
        defines the global row order. No data moves at construction."""
        return cls(shards)

    @property
    def shards(self) -> tuple:
        return self._shards

    @property
    def num_shards(self) -> int:
        return len(self._shards)

    @property
    def offsets(self) -> np.ndarray:
        """Global start row of each shard, plus a final total-n entry —
        shape ``(num_shards + 1,)``."""
        return self._offsets.copy()

    @property
    def max_shard_rows(self) -> int:
        """Rows of the largest shard — the per-machine n the residency
        model (``engine.resolve_block_rows``) is solved against."""
        return max(s.n for s in self._shards)

    @property
    def n(self) -> int:
        return int(self._offsets[-1])

    @property
    def d(self) -> int:
        return self._shards[0].d

    def host_blocks(self, block_rows: int) -> Iterator[np.ndarray]:
        """Numpy blocks with no device transfer: each shard's stream in
        shard order (= global row order)."""
        rows = _check_rows(block_rows)
        for s in self._shards:
            if hasattr(s, "host_blocks"):
                yield from s.host_blocks(rows)
            else:
                for blk in s.blocks(rows):
                    yield np.asarray(blk, np.float32)

    def blocks(self, block_rows: int, *,
               prefetch: int = DEFAULT_PREFETCH) -> Iterator[jnp.ndarray]:
        return stream_device(self.host_blocks(block_rows), prefetch)

    def _locate(self, idx: np.ndarray) -> np.ndarray:
        return np.searchsorted(self._offsets, idx, side="right") - 1

    def row(self, idx: int) -> np.ndarray:
        if not 0 <= idx < self.n:
            raise IndexError(f"row {idx} out of range for n={self.n}")
        s = int(self._locate(np.asarray([idx]))[0])
        return np.asarray(self._shards[s].row(int(idx - self._offsets[s])),
                          np.float32)

    def take(self, indices) -> np.ndarray:
        """Gather rows across shards — each shard's ``take`` is called
        once with its (order-preserved) share of the indices."""
        idx = _check_take_indices(indices, self.n)
        out = np.empty((idx.size, self.d), np.float32)
        shard = self._locate(idx)
        for s in np.unique(shard):
            sel = shard == s
            out[sel] = np.asarray(
                self._shards[s].take(idx[sel] - self._offsets[s]),
                np.float32)
        return out

    @property
    def has_weights(self) -> bool:
        return any(has_weights(s) for s in self._shards)

    def weights_of(self, start: int, rows: int) -> np.ndarray:
        stop = min(start + rows, self.n)
        out = np.ones((max(stop - start, 0),), np.float32)
        pos = start
        while pos < stop:
            s = int(self._locate(np.asarray([pos]))[0])
            off = int(self._offsets[s])
            hi = min(stop, int(self._offsets[s + 1]))
            out[pos - start:hi - start] = weights_of(
                self._shards[s], pos - off, hi - pos)
            pos = hi
        return out

    def take_weights(self, indices) -> np.ndarray:
        idx = _check_take_indices(indices, self.n)
        out = np.ones((idx.size,), np.float32)
        shard = self._locate(idx)
        for s in np.unique(shard):
            sel = shard == s
            out[sel] = take_weights(self._shards[s],
                                    idx[sel] - self._offsets[s])
        return out

    def materialize(self) -> jnp.ndarray:
        return jnp.concatenate(
            [jnp.asarray(b) for b in self.host_blocks(1 << 20)], axis=0)


class RemoteShard:
    """Metadata stand-in for a shard whose rows live on another process.

    In a genuine ``jax.distributed`` run no process can read another
    machine's shard, but every process must still know the *global*
    partition (shard sizes define global row ids, mask shapes, and the
    lockstep step count). ``RemoteShard`` carries exactly that — ``n``
    and ``d`` — and raises on any data access, which is what makes the
    "no process ever materializes more than its own shard" contract
    structural rather than aspirational: there is simply no code path
    that can pull a remote row onto this host.
    """

    is_remote = True

    def __init__(self, n: int, d: int, *, process: int = 0):
        if n < 0:
            raise ValueError(f"n must be >= 0, got {n}")
        if d < 1:
            raise ValueError(f"d must be >= 1, got {d}")
        self._n = int(n)
        self._d = int(d)
        self._process = int(process)

    @property
    def n(self) -> int:
        return self._n

    @property
    def d(self) -> int:
        return self._d

    @property
    def process(self) -> int:
        """The controller process that owns (and feeds) this shard."""
        return self._process

    def _no_data(self, op: str):
        raise RuntimeError(
            f"shard data lives on process {self._process}; {op} cannot run "
            "here — multi-process folds read only local shards, and rows "
            "move between processes only through the O(k) candidate "
            "exchange (ProcessShardedSource.take)")

    def blocks(self, block_rows: int):
        self._no_data("blocks()")

    def host_blocks(self, block_rows: int):
        self._no_data("host_blocks()")

    def row(self, idx: int):
        self._no_data("row()")

    def take(self, indices):
        self._no_data("take()")

    def materialize(self):
        self._no_data("materialize()")


class ProcessShardedSource(ShardedSource):
    """A ``ShardedSource`` whose remote shards are ``RemoteShard`` stubs —
    the input model of a genuine multi-process run.

    Every process constructs the *same global partition* (same shard
    sizes, same order — global row ids agree everywhere) but holds real
    data only for its own shards. Streaming consumers (``MeshExecutor``)
    read local shards and skip the stubs; random access (``take`` /
    ``row``) is the paper's O(k) candidate exchange: each process gathers
    its own rows into a zero-filled buffer, the buffers are all-gathered
    (``compat.exchange_host``), and each row is *selected* from its
    owning process's contribution — pure data movement, bitwise exact,
    with O(|indices| · d) bytes on the wire and never a full shard.

    ``take`` is a collective: every process must call it with identical
    indices (the SPMD drivers do — their host state is replicated by
    construction). ``materialize`` stays structurally impossible.
    """

    def __init__(self, shards: Sequence):
        super().__init__(shards)
        self._local_ids = tuple(
            i for i, s in enumerate(self.shards)
            if not getattr(s, "is_remote", False))
        if not self._local_ids:
            raise ValueError(
                "ProcessShardedSource needs at least one local shard on "
                "this process")

    @classmethod
    def for_process(cls, local, sizes: Sequence[int],
                    process_id: int) -> "ProcessShardedSource":
        """The canonical one-shard-per-process layout: ``local`` is this
        process's source, ``sizes`` the global per-shard row counts (same
        list on every process), ``process_id`` this shard's position."""
        local = as_source(local)
        sizes = [int(s) for s in sizes]
        if not 0 <= process_id < len(sizes):
            raise ValueError(
                f"process_id {process_id} out of range for "
                f"{len(sizes)} shards")
        if local.n != sizes[process_id]:
            raise ValueError(
                f"local shard has {local.n} rows but sizes[{process_id}] "
                f"says {sizes[process_id]} — the global partition must "
                "agree across processes")
        shards = [local if i == process_id
                  else RemoteShard(sizes[i], local.d, process=i)
                  for i in range(len(sizes))]
        return cls(shards)

    @property
    def local_shard_ids(self) -> tuple:
        """Indices of the shards whose data lives on this process."""
        return self._local_ids

    def _owner_process(self, shard: np.ndarray) -> np.ndarray:
        me = compat.process_index()
        owners = np.asarray(
            [getattr(s, "process", me) if getattr(s, "is_remote", False)
             else me for s in self.shards], np.int64)
        return owners[shard]

    def take(self, indices) -> np.ndarray:
        idx = _check_take_indices(indices, self.n)
        shard = self._locate(idx)
        vals = np.zeros((idx.size, self.d), np.float32)
        for s in self._local_ids:
            sel = shard == s
            if sel.any():
                vals[sel] = np.asarray(
                    self.shards[s].take(idx[sel] - self._offsets[s]),
                    np.float32)
        if compat.process_count() == 1:
            remote = ~np.isin(shard, np.asarray(self._local_ids))
            if remote.any():
                raise RuntimeError(
                    "take() hit a remote shard but the runtime is "
                    "single-process — nobody can contribute those rows")
            return vals
        gathered = compat.exchange_host(vals)        # (P, |idx|, d)
        owner = self._owner_process(shard)
        return gathered[owner, np.arange(idx.size)]

    def row(self, idx: int) -> np.ndarray:
        if not 0 <= idx < self.n:
            raise IndexError(f"row {idx} out of range for n={self.n}")
        return self.take(np.asarray([idx]))[0]


class WeightedSource:
    """Any source plus per-row f32 weights — a weighted instance.

    The weighted objectives of Ceccarello et al. (1802.09205) operate on
    points carrying multiplicities (coreset points standing in for their
    clusters). ``WeightedSource`` attaches a host-resident ``(n,)`` f32
    weight vector to an arbitrary parent source; the points themselves are
    delegated untouched (same blocks, same bits), and consumers fetch the
    weight slice aligned with each block via ``weights_of(start, rows)``.
    Weights are O(n) *host* floats — 4 bytes/row, the same budget class as
    the streamed EIM's host-resident relations — never device-resident as
    a whole.

    Weights must be finite and non-negative; ``w == 0`` marks a row as
    absent from the instance (weighted folds gate it out of candidacy).
    """

    def __init__(self, parent, weights):
        parent = as_source(parent)
        w = np.asarray(weights, np.float32).reshape(-1)
        if w.shape[0] != parent.n:
            raise ValueError(
                f"weights have {w.shape[0]} rows, source has {parent.n}")
        if w.size and (not np.all(np.isfinite(w)) or w.min() < 0):
            raise ValueError("weights must be finite and non-negative")
        self._parent = parent
        self._w = w

    @property
    def parent(self):
        return self._parent

    @property
    def has_weights(self) -> bool:
        return True

    @property
    def n(self) -> int:
        return self._parent.n

    @property
    def d(self) -> int:
        return self._parent.d

    def weights_of(self, start: int, rows: int) -> np.ndarray:
        return self._w[start:start + rows]

    def take_weights(self, indices) -> np.ndarray:
        return self._w[_check_take_indices(indices, self.n)]

    def host_blocks(self, block_rows: int) -> Iterator[np.ndarray]:
        if hasattr(self._parent, "host_blocks"):
            yield from self._parent.host_blocks(block_rows)
        else:
            for blk in self._parent.blocks(block_rows):
                yield np.asarray(blk, np.float32)

    def blocks(self, block_rows: int, *,
               prefetch: int = DEFAULT_PREFETCH) -> Iterator[jnp.ndarray]:
        try:
            return self._parent.blocks(block_rows, prefetch=prefetch)
        except TypeError:
            return self._parent.blocks(block_rows)

    def row(self, idx: int) -> np.ndarray:
        return self._parent.row(idx)

    def take(self, indices) -> np.ndarray:
        return self._parent.take(indices)

    def materialize(self) -> jnp.ndarray:
        return self._parent.materialize()


def _shard_count(shards, shard_axes=None) -> int:
    """Shard count from an int, a ``jax.sharding.Mesh`` (product of the
    ``shard_axes`` sizes; default all axes), or anything exposing
    ``num_shards`` (e.g. a ``MeshExecutor``)."""
    if isinstance(shards, int):
        return shards
    if hasattr(shards, "num_shards"):        # MeshExecutor / ShardedSource
        return int(shards.num_shards)
    if hasattr(shards, "shape") and hasattr(shards, "axis_names"):  # Mesh
        axes = tuple(shard_axes) if shard_axes is not None \
            else tuple(shards.axis_names)
        count = 1
        for ax in axes:
            count *= int(shards.shape[ax])
        return count
    raise TypeError(
        f"shards must be an int, a Mesh, or expose num_shards; got "
        f"{type(shards).__name__}")


def shard_source(source, shards, *, shard_axes=None) -> ShardedSource:
    """Split ``source`` into a ``ShardedSource`` of contiguous row views.

    ``shards`` is a shard count, a ``jax.sharding.Mesh`` (the count is the
    product of the ``shard_axes`` sizes; default: every mesh axis), or a
    ``MeshExecutor`` — whatever names the machine blocking. The split is
    the paper's: ``per = ceil(n / S)`` rows per machine, machine ``i``
    holding rows ``[i·per, min((i+1)·per, n))`` — exactly
    ``SimExecutor``'s blocking, which is what makes sharded runs bitwise
    comparable to the simulated-machines path. Each shard is a
    ``SliceSource`` (three integers of state): splitting copies nothing
    and materializes nothing.

    An input that is already a ``ShardedSource`` passes through when its
    shard count matches (and raises when it doesn't — a mis-sharded input
    silently re-split would hide a real partitioning bug).

    >>> import numpy as np
    >>> src = HostSource(np.zeros((10, 2), np.float32))
    >>> sh = shard_source(src, 4)          # per = ceil(10/4) = 3
    >>> [s.n for s in sh.shards]
    [3, 3, 3, 1]
    >>> sh.n, sh.num_shards
    (10, 4)
    """
    src = as_source(source)
    count = _shard_count(shards, shard_axes)
    if count < 1:
        raise ValueError(f"need at least one shard, got {count}")
    if isinstance(src, ShardedSource):
        if src.num_shards != count:
            raise ValueError(
                f"source is already sharded {src.num_shards} ways, "
                f"expected {count} — re-shard explicitly if intended")
        return src
    per = -(-src.n // count)
    return ShardedSource([
        SliceSource(src, min(i * per, src.n), min((i + 1) * per, src.n))
        for i in range(count)])


def _philox_at(seed: int, offset: int) -> np.random.Generator:
    """Generator positioned at double-draw ``offset`` of the Philox stream.

    numpy's ``Philox.advance(delta)`` moves in whole 4x64 counter blocks
    (4 doubles each), so advance to the containing block and discard the
    remainder."""
    bg = np.random.Philox(key=np.uint64(seed))
    bg.advance(offset // 4)
    g = np.random.Generator(bg)
    if offset % 4:
        g.random(offset % 4)
    return g


def _child_seed(seed: int, start: int) -> np.random.Generator:
    ss = np.random.SeedSequence(entropy=[np.uint64(seed), np.uint64(start)])
    return np.random.Generator(np.random.Philox(ss))


def synthetic_source(name: str, n: int, *, seed: int = 0,
                     **kwargs) -> SyntheticSource:
    """Out-of-core view of a ``data/pointsets.py`` family (§7.3).

    ``unif`` is bitwise-identical to ``pointsets.unif(n, ...)`` under any
    blocking. ``gau``/``unb`` share the monolithic generator's cluster
    centers but draw per-block assignments/noise from child seeds
    (distribution-identical). Other families use per-block child seeds.

    >>> s = synthetic_source("unif", 100, d=2, seed=0)
    >>> s.n, s.d
    (100, 2)
    >>> s.take([0, 1]).shape        # regenerated, never stored
    (2, 2)
    """
    if name == "unif":
        d = int(kwargs.get("d", 2))
        side = float(kwargs.get("side", 100.0))

        def block_fn(start: int, rows: int) -> np.ndarray:
            g = _philox_at(seed, start * d)
            return (g.random((rows, d)) * side).astype(np.float32)

        return SyntheticSource(block_fn, n, d, name=name)

    if name in ("gau", "unb"):
        gen = pointsets.GENERATORS[name]
        k_prime = int(kwargs.get("k_prime", 25))
        d = int(kwargs.get("d", 2))
        side = float(kwargs.get("side", 100.0))
        # Centers are the monolithic generator's first draw — shared across
        # every block so the cluster structure is global, not per-block.
        centers = (pointsets._rng(seed).random((k_prime, d)) * side
                   ).astype(np.float32)

        def block_fn(start: int, rows: int) -> np.ndarray:
            child = int(_child_seed(seed, start).integers(0, 2 ** 63))
            return gen(rows, k_prime, d, seed=child, centers=centers,
                       **{k: v for k, v in kwargs.items()
                          if k not in ("k_prime", "d", "side")})

        return SyntheticSource(block_fn, n, d, name=name)

    if name in pointsets.GENERATORS:
        gen = pointsets.GENERATORS[name]

        def block_fn(start: int, rows: int) -> np.ndarray:
            child = int(_child_seed(seed, start).integers(0, 2 ** 63))
            return gen(rows, seed=child, **kwargs)

        return SyntheticSource(block_fn, n, name=name)

    raise ValueError(f"unknown generator {name!r}; "
                     f"have {sorted(pointsets.GENERATORS)}")
