#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): the full test suite must pass.
# Usage: scripts/tier1.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Contract lint: repo-specific invariants (stdlib-only, always available).
python -m tools.reprolint src benchmarks examples

# Generic lint: pyflakes + import order via ruff (pyproject.toml).
# Gated: ruff is a dev dependency some environments lack; CI's lint job
# always runs it.
if command -v ruff >/dev/null 2>&1; then
    ruff check .
fi

exec python -m pytest -x -q "$@"
