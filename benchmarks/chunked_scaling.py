"""Chunked-engine scaling: radius vs runtime vs memory budget as n grows.

Demonstrates the kernels/engine.py capacity model end-to-end:

  * un-chunked ``assign_nearest`` materializes an (n, m) f32 block —
    4·n·m bytes of working memory; at n = 10⁷, m = 256 that is ~10 GiB,
    far beyond a stated per-pass budget (and beyond small-device HBM);
  * the chunked path streams row-blocks under ``memory_budget`` bytes and
    completes at any n that fits *points* in memory, with the same result.

Each row reports the streamed working set (from the engine's model
``4·chunk·(m+d) + 4·m·d``) next to what the un-chunked block would have
needed, plus GON radius invariance at a smaller n as a correctness anchor.

The **out-of-core section** goes one level further (data/source.py +
core/executor.py): full MRG over a ``HostSource``/``MemmapSource`` at an n
whose entire (n, d) f32 array exceeds a stated device budget — enforced
with an assert — so the *points* are bounded by host RAM / disk, not HBM;
only ring-buffered super-shards under ``memory_budget`` plus the k·M
center union are ever device-resident. A
smaller-n row parity-checks centers/radius bitwise against the in-memory
``mrg_sim`` on the same blocking.

The **EIM section** (``eim_out_of_core_rows``) repeats the exercise for
the paper's §4 sampling algorithm: streamed EIM over a ``MemmapSource``
at an n past the same kind of asserted budget (its per-point relations
live on the host; the counter-based Round-1 sampler needs no data pass),
plus a bitwise device-vs-streamed sample parity anchor.

The **compacted-R section** (``eim_compaction_rows``) asserts the
shrinking-|R| iteration cost of the production path: per-iteration pass
row-counts (metered at ``run_filter_round``) must shrink monotonically
below n once ``compact_threshold`` engages, the view's gathers must stay
within the budget-derived super-shard, and the compacted sample must be
bitwise the fixed-shape streamed sample.

The **sharded section** (``sharded_out_of_core_rows``) closes the loop on
the paper's machine model: per-host shard sources feed a 4-shard
``MeshExecutor`` (subprocess with forced host devices) under an asserted
per-shard ``memory_budget`` — a source-read spy proves no host-side
full-n (or even full-shard) materialization on the path, and a
smaller-n anchor pins the sharded result bitwise against ``mrg_sim``.

Run: ``PYTHONPATH=src python -m benchmarks.chunked_scaling [--full]``
(``--full`` pushes n to 10⁷; default tops out at 10⁶ to stay friendly to
one CPU core). Also callable as ``run()`` yielding benchmarks/run.py-style
``(name, us_per_call, derived)`` rows.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HostStreamExecutor, eim, eim_sample, gonzalez, mrg, \
    mrg_sim
from repro.data import HostSource, MemmapSource
from repro.kernels import engine, ops

from .kernel_bench import _t

M = 256           # centers
D = 8             # embedding dim kept small so points fit at n=1e7
BUDGET = 64 * 2 ** 20   # 64 MiB per-pass working-set budget


def run(full: bool = False):
    """Yields (name, us_per_call, derived) CSV rows."""
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.normal(size=(M, D)).astype(np.float32))

    n_grid = [10_000, 100_000, 1_000_000]
    if full:
        n_grid.append(10_000_000)

    for n in n_grid:
        x = jnp.asarray(rng.normal(size=(n, D)).astype(np.float32))
        unchunked_bytes = 4 * n * M
        chunk = engine.resolve_chunk(n, M, D, memory_budget=BUDGET)
        streamed_bytes = 4 * chunk * (M + D) + 4 * M * D
        over = unchunked_bytes > BUDGET

        t_c = _t(lambda a: ops.assign_nearest(a, c, impl="ref",
                                              memory_budget=BUDGET), x)
        yield (f"assign_chunked_n{n}", t_c * 1e6,
               f"ws={streamed_bytes / 2**20:.1f}MiB"
               f"(unchunked={unchunked_bytes / 2**20:.0f}MiB"
               f"{'>' if over else '<='}budget={BUDGET / 2**20:.0f}MiB)")

        # Un-chunked comparison only where its block respects the budget —
        # past that point the chunked engine is the only path that honors
        # the capacity model (the paper's c < n regime).
        if not over:
            t_u = _t(lambda a: ops.assign_nearest(a, c, impl="ref"), x)
            yield (f"assign_unchunked_n{n}", t_u * 1e6,
                   f"overhead={t_c / t_u:.2f}x")
        del x

    # Radius-vs-runtime anchor: GON radius is chunk-invariant while the
    # working set shrinks by orders of magnitude.
    n = 200_000 if full else 50_000
    x = jnp.asarray(rng.normal(size=(n, D)).astype(np.float32))
    k = 16
    r0 = float(jnp.sqrt(gonzalez(x, k, impl="ref").radius2))
    for chunk in (None, 65536, 4096):
        t = _t(lambda a: gonzalez(a, k, impl="ref", chunk=chunk), x)
        r = float(jnp.sqrt(gonzalez(x, k, impl="ref", chunk=chunk).radius2))
        tag = "none" if chunk is None else str(chunk)
        yield (f"gon_n{n}_k{k}_chunk{tag}", t * 1e6,
               f"radius={r:.5g}(drift={abs(r - r0):.1e})")
    del x

    yield from out_of_core_rows(full)
    yield from sharded_out_of_core_rows(full)


def out_of_core_rows(full: bool = False):
    """MRG past the device budget: the input lives on host RAM / disk.

    The stated HBM budget covers everything device-resident at once — the
    whole (n, d) array is *asserted* not to fit it, so the legacy
    device-array path is structurally impossible at this n; the
    ``HostStreamExecutor`` completes within a quarter of the budget for
    its DMA'd super-shards (two coexist under double buffering — the
    engine's residency model counts both) plus the k·M center union.
    """
    k = 16
    device_budget = (256 if full else 32) * 2 ** 20
    n = 12_000_000 if full else 1_500_000
    full_bytes = 4 * n * D
    assert full_bytes > device_budget, (
        f"out-of-core demo misconfigured: (n={n}, d={D}) f32 is "
        f"{full_bytes / 2**20:.0f}MiB, within the {device_budget / 2**20:.0f}"
        f"MiB device budget")
    ex = HostStreamExecutor(memory_budget=device_budget // 4)
    rows = engine.resolve_block_rows(n, D, memory_budget=device_budget // 4)
    rng = np.random.default_rng(7)
    x = rng.normal(size=(n, D)).astype(np.float32)

    def timed(fn):
        t0 = time.time()
        res = fn()
        jax.block_until_ready(res.centers)
        return time.time() - t0, res

    t_host, r_host = timed(lambda: mrg(HostSource(x), k, executor=ex))
    yield (f"oocore_mrg_host_n{n}", t_host * 1e6,
           f"points={full_bytes / 2**20:.0f}MiB>budget="
           f"{device_budget / 2**20:.0f}MiB;shard={rows}rows="
           f"{4 * rows * D / 2**20:.1f}MiB;radius={float(jnp.sqrt(r_host.radius2)):.4g}")

    tmp = tempfile.mkdtemp(prefix="oocore_shards_")
    try:
        ms = MemmapSource.save_shards(x, tmp, rows_per_shard=max(rows // 2, 1))
        del x  # host array gone: the memmap run reads only from disk
        t_mm, r_mm = timed(lambda: mrg(ms, k, executor=ex))
        drift = abs(float(jnp.sqrt(r_mm.radius2)) -
                    float(jnp.sqrt(r_host.radius2)))
        yield (f"oocore_mrg_memmap_n{n}", t_mm * 1e6,
               f"shards={ms.num_shards};radius={float(jnp.sqrt(r_mm.radius2)):.4g}"
               f"(host_drift={drift:.1e})")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # Correctness anchor at a smaller n: identical blocking => centers and
    # radius must match the in-memory mrg_sim bitwise.
    n_s, rows_s = 65_536, 8_192
    xs = rng.normal(size=(n_s, D)).astype(np.float32)
    r_mem = mrg_sim(jnp.asarray(xs), k, m=n_s // rows_s, impl="ref")
    r_str = mrg(HostSource(xs), k,
                executor=HostStreamExecutor(block_rows=rows_s), impl="ref")
    exact = (np.asarray(r_mem.centers) == np.asarray(r_str.centers)).all() \
        and float(r_mem.radius2) == float(r_str.radius2)
    yield (f"oocore_parity_n{n_s}", 0,
           f"bitwise={'exact' if exact else 'DRIFT'};"
           f"radius={float(jnp.sqrt(r_str.radius2)):.5g}")

    yield from eim_out_of_core_rows(full, rng)


def eim_out_of_core_rows(full: bool, rng: np.random.Generator):
    """EIM past the device budget (paper §4 at the out-of-core regime).

    The φ-sampler's per-point relations (r/s masks, d(x,S)) are host-
    resident; every pass is a fold over the source's budget-bounded
    super-shards, so the *asserted* condition is the same as MRG's: the
    whole (n, d) f32 array exceeds the stated device budget — the
    materializing path is structurally impossible at this n — while the
    streamed EIM completes within a quarter of the budget for its ring-
    buffered shards. A smaller-n anchor checks the streamed sample is
    *bitwise identical* to the jitted device path for the same key (the
    counter-based sampler + value-fold rounds make it blocking-invariant).
    """
    k = 4
    device_budget = (64 if full else 4) * 2 ** 20
    n = 2_000_000 if full else 150_000
    full_bytes = 4 * n * D
    assert full_bytes > device_budget, (
        f"out-of-core EIM demo misconfigured: (n={n}, d={D}) f32 is "
        f"{full_bytes / 2**20:.0f}MiB, within the "
        f"{device_budget / 2**20:.0f}MiB device budget")
    ex = HostStreamExecutor(memory_budget=device_budget // 4)
    key = jax.random.PRNGKey(0)
    x = rng.normal(size=(n, D)).astype(np.float32)

    tmp = tempfile.mkdtemp(prefix="oocore_eim_shards_")
    try:
        rows = ex.rows_for(HostSource(x))
        ms = MemmapSource.save_shards(x, tmp, rows_per_shard=max(rows // 2, 1))
        del x  # the EIM run reads only from disk
        t0 = time.time()
        res = eim(ms, k, key, impl="ref", executor=ex)
        jax.block_until_ready(res.centers)
        t = time.time() - t0
        yield (f"oocore_eim_memmap_n{n}", t * 1e6,
               f"points={full_bytes / 2**20:.0f}MiB>budget="
               f"{device_budget / 2**20:.0f}MiB;shard={rows}rows;"
               f"iters={int(res.sample.iters)};"
               f"|C|={int(np.asarray(res.sample.sample_mask).sum())};"
               f"radius={float(jnp.sqrt(res.radius2)):.4g}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # Parity anchor: streamed sample == device sample bitwise, same key.
    n_s = 65_536
    xs = rng.normal(size=(n_s, D)).astype(np.float32)
    s_dev = eim_sample(jnp.asarray(xs), k, key, impl="ref")
    s_str = eim_sample(HostSource(xs), k, key, impl="ref",
                       executor=HostStreamExecutor(block_rows=8_192))
    exact = (np.array_equal(np.asarray(s_dev.sample_mask),
                            np.asarray(s_str.sample_mask))
             and np.array_equal(np.asarray(s_dev.s_mask),
                                np.asarray(s_str.s_mask))
             and int(s_dev.iters) == int(s_str.iters))
    assert exact, "streamed EIM sample drifted from the device path"
    yield (f"oocore_eim_parity_n{n_s}", 0,
           f"bitwise={'exact' if exact else 'DRIFT'};"
           f"iters={int(s_str.iters)};"
           f"sample={int(np.asarray(s_str.sample_mask).sum())}")

    yield from eim_compaction_rows(full, rng)


class _MeteredExecutor(HostStreamExecutor):
    """Records the view size each filter round streams (= the rows the
    per-iteration pass touches)."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.pass_rows = []

    def run_filter_round(self, source, *a, **kw):
        self.pass_rows.append(source.n)
        return super().run_filter_round(source, *a, **kw)


class _MeteredSource(HostSource):
    """HostSource recording the largest single block/gather it served."""

    def __init__(self, x):
        super().__init__(x)
        self.max_block = 0

    def host_blocks(self, block_rows):
        for blk in super().host_blocks(block_rows):
            self.max_block = max(self.max_block, blk.shape[0])
            yield blk

    def take(self, indices):
        out = super().take(indices)
        self.max_block = max(self.max_block, out.shape[0])
        return out


def eim_compaction_rows(full: bool, rng: np.random.Generator):
    """Compacted-R streamed EIM (paper §4's shrinking round cost).

    The fixed-shape streamed loop pays O(n·|S_new|) every iteration; with
    ``compact_threshold`` the fold re-points at an ``IndexedSource`` of
    the survivors, so iteration l touches |R_l| rows — *asserted* here by
    metering the view size of every filter round (it must shrink
    monotonically below n), while a metered source asserts the out-of-core
    budget still holds during the view's gathers (no block or take ever
    exceeds the budget-derived super-shard). Both runs are the production
    path and must return bitwise-identical samples.
    """
    k, eps, phi = 4, 0.05, 5.0
    n = 400_000 if full else 120_000
    device_budget = (32 if full else 8) * 2 ** 20
    ex_budget = device_budget // 4
    x = rng.normal(size=(n, D)).astype(np.float32)
    key = jax.random.PRNGKey(3)

    def timed_run(compact_threshold):
        src = _MeteredSource(x)
        ex = _MeteredExecutor(memory_budget=ex_budget)
        t0 = time.time()
        s = eim_sample(src, k, key, eps=eps, phi=phi, impl="ref",
                       executor=ex, compact_threshold=compact_threshold)
        return time.time() - t0, s, ex, src

    t_base, s_base, ex_base, _ = timed_run(0.0)
    t_comp, s_comp, ex_comp, src_comp = timed_run(1.0)

    rows = _MeteredExecutor(memory_budget=ex_budget).rows_for(HostSource(x))
    assert ex_base.pass_rows == [n] * int(s_base.iters), \
        "baseline pass must touch all n rows every iteration"
    passes = ex_comp.pass_rows
    assert passes[0] == n and passes[-1] < n and \
        all(a >= b for a, b in zip(passes, passes[1:])), \
        f"per-iteration pass row-count failed to shrink: {passes}"
    assert src_comp.max_block <= rows, \
        "a gathered block exceeded the memory-budget super-shard"
    assert (np.array_equal(np.asarray(s_base.sample_mask),
                           np.asarray(s_comp.sample_mask))
            and int(s_base.iters) == int(s_comp.iters)), \
        "compacted sample drifted from the fixed-shape streamed path"
    yield (f"compactR_eim_baseline_n{n}", t_base * 1e6,
           f"iters={int(s_base.iters)};pass_rows={n}x{int(s_base.iters)}")
    yield (f"compactR_eim_n{n}", t_comp * 1e6,
           f"pass_rows={'/'.join(str(p) for p in passes)};"
           f"max_block={src_comp.max_block}<=shard={rows};"
           f"speedup={t_base / t_comp:.2f}x")


_SHARDED_PROG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
import json, time
import jax
import jax.numpy as jnp
import numpy as np
from repro import compat
from repro.core import MeshExecutor, mrg, mrg_sim
from repro.data import HostSource, ShardedSource, shard_source


class SpyShard(HostSource):
    def __init__(self, x):
        super().__init__(x)
        self.max_read = 0
        self.materialized = False

    def host_blocks(self, block_rows):
        for blk in super().host_blocks(block_rows):
            self.max_read = max(self.max_read, blk.shape[0])
            yield blk

    def take(self, indices):
        out = super().take(indices)
        self.max_read = max(self.max_read, out.shape[0])
        return out

    def materialize(self):
        self.materialized = True
        return super().materialize()


S, D, k = {devices}, {D}, 16
n, device_budget = {n}, {budget}
full_bytes = 4 * n * D
assert full_bytes > device_budget, "sharded demo misconfigured"
shard_budget = device_budget // (2 * S)
mesh = compat.make_mesh(np.array(jax.devices()[:S]), ("data",))
rng = np.random.default_rng(11)
x = rng.normal(size=(n, D)).astype(np.float32)
per = -(-n // S)
shards = [SpyShard(x[i * per:(i + 1) * per]) for i in range(S)]
sh = ShardedSource.from_per_host_shards(shards)
ex = MeshExecutor(mesh, memory_budget=shard_budget)
rows = ex.rows_for(sh)
assert rows * 4 * (D + 1) * (1 + ex.prefetch) <= shard_budget
t0 = time.time()
res = mrg(sh, k, executor=ex, impl="ref")
jax.block_until_ready(res.centers)
t = time.time() - t0
assert all(s.max_read <= rows for s in shards), "spy: oversized shard read"
assert not any(s.materialized for s in shards), "spy: full-shard materialize"

# parity anchor: one block per shard == mrg_sim's m-machine blocking
n_s = 65536
xs = rng.normal(size=(n_s, D)).astype(np.float32)
r_sim = mrg_sim(jnp.asarray(xs), k, m=S, impl="ref")
r_sh = mrg(shard_source(HostSource(xs), S), k,
           executor=MeshExecutor(mesh, block_rows=n_s // S), impl="ref")
exact = (np.asarray(r_sim.centers) == np.asarray(r_sh.centers)).all() \\
    and float(r_sim.radius2) == float(r_sh.radius2)
print(json.dumps([
    {{"name": "sharded_mrg_mesh_n%d" % n, "us": t * 1e6,
      "derived": "shards=%d;points=%.0fMiB>budget=%.0fMiB;"
                 "per_shard=%.1fMiB;rows=%d;max_read=%d;radius=%.4g"
                 % (S, full_bytes / 2**20, device_budget / 2**20,
                    shard_budget / 2**20, rows,
                    max(s.max_read for s in shards),
                    float(jnp.sqrt(res.radius2)))}},
    {{"name": "sharded_parity_n%d" % n_s, "us": 0,
      "derived": "bitwise=%s;vs=mrg_sim_m%d"
                 % ("exact" if exact else "DRIFT", S)}},
]))
assert exact, "sharded mesh MRG drifted from mrg_sim"
"""


def sharded_out_of_core_rows(full: bool = False):
    """Sharded out-of-core MRG: no host ever holds n (paper §3's model).

    Runs in a subprocess with ``--xla_force_host_platform_device_count``
    (the main process keeps its single-device view, like
    tests/test_distributed.py): per-host ``SpyShard`` sources feed a
    4-shard ``MeshExecutor`` under an *asserted* per-shard
    ``memory_budget`` — the spy proves no shard ever served a read larger
    than the budget-derived super-shard and nothing materialized a full
    shard, while the whole (n, d) array is asserted not to fit the stated
    device budget. A smaller-n anchor pins the sharded path bitwise
    against ``mrg_sim``'s m-machine blocking.
    """
    import json
    import os
    import subprocess
    import sys

    import repro

    devices = 4
    n = 12_000_000 if full else 1_200_000
    budget = (256 if full else 32) * 2 ** 20
    prog = _SHARDED_PROG.format(devices=devices, D=D, n=n, budget=budget)
    env = dict(os.environ)
    # repro is a namespace package (no __init__.py): locate it by __path__.
    src_dir = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env["PYTHONPATH"] = src_dir + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded out-of-core cell failed:\n{out.stderr[-3000:]}")
    for row in json.loads(out.stdout.strip().splitlines()[-1]):
        yield (row["name"], row["us"], row["derived"])


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="extend n to 10^7 (the paper-scale capacity demo)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    main()
