"""Multi-process cluster benchmark — wall-clock of genuine 2-process
``jax.distributed`` runs on localhost (CPU, gloo collectives).

Opt-in only (``--only cluster``): every row spawns real worker
interpreters, so the dominant cost at quick sizes is process bring-up
(imports + coordinator handshake), reported as its own row so the mrg
row can be read against it. Not part of the default CI bench list.
"""
from __future__ import annotations

import time
from typing import Iterator, Tuple

from repro.launch.cluster import run_scenario

_TARGET = "repro.launch.cluster:demo_mrg"


def _timed(num_processes: int, n_per: int, k: int) -> Tuple[float, dict]:
    t0 = time.perf_counter()
    verdicts = run_scenario(_TARGET, num_processes,
                            args={"n_per_process": n_per, "k": k},
                            timeout=600.0)
    dt = time.perf_counter() - t0
    first = verdicts[0]
    agree = all(v.get("centers") == first.get("centers")
                for v in verdicts[1:])
    if not agree:  # pragma: no cover - would be a parity regression
        raise RuntimeError("cluster processes disagree on centers")
    return dt, first


def run(full: bool = False) -> Iterator[Tuple[str, float, str]]:
    procs = 2
    # bring-up floor: a near-empty problem is all spawn + initialize
    dt, _ = _timed(procs, n_per=256, k=2)
    yield (f"cluster_spawn_p{procs}", dt * 1e6,
           f"n_per=256;k=2;bringup_s={dt:.2f}")

    n_per = 65_536 if full else 8_192
    k = 16
    dt, v = _timed(procs, n_per=n_per, k=k)
    yield (f"cluster_mrg_p{procs}", dt * 1e6,
           f"n={v['n']};k={k};radius={v['radius']:.4g};"
           f"rounds={v['rounds']};wall_s={dt:.2f}")
