"""Roofline analysis (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh):

  compute_s    = FLOPS_global    / (chips × 197e12)
  memory_s     = BYTES_global    / (chips × 819e9)
  collective_s = COLL_global     / (chips × 50e9)

COLL comes from the dry-run JSON (post-SPMD HLO parse with while-trip
expansion). FLOPS/BYTES use the **analytic model below** because XLA's
``cost_analysis()`` counts while-loop (=lax.scan) bodies once — a 61-layer
scanned stack under-counts ~61× (verified empirically; the raw
cost_analysis numbers are kept in the JSON for reference).

Analytic model (documented assumptions; global per step):
  FLOPS:
    matmul fwd              = 2 · N_active · T
    attention fwd           = Σ_layers 4·B·S_q·S_visible·H·hd  (causal ⇒
                              S_vis = S/2 for global, min(W,S) for window;
                              decode: S_vis = S_cache)
    SSD fwd                 = B·S·nh·(4·Q·N_state + 2·Q·P + 6·N_state·P)/…
                              per layer (chunk Q — intra-chunk quadratic +
                              state update/emit)
    train                   = fwd × (2 backward + 1 forward) + fwd × refwd
                              (refwd = 1 with full remat; remat_block adds
                              +1/k, folded into ×(4))
    prefill                 = fwd ;   decode = fwd(T=B, S_vis=S_cache)
  BYTES:
    params traffic          = N_bytes × (reads: fwd+bwd+refwd = 3; +2
                              writes param+grad) (train) / 1 read (serve)
    optimizer               = adam 16 B/param r/w ; adafactor ~0 (factored)
    activations             = L · T · (8·D + 4·F_eff) · 2 B × passes
    CE logits               = T · V · 4 B × (fwd + recompute) × 2 (r+w)
    KV cache (decode)       = full cache read + 1-token write
"""
from __future__ import annotations

import json
import os
from typing import Dict

from repro.configs import SHAPES, get_config
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _attn_flops_fwd(cfg: ModelConfig, B, S_q, S_cache=None):
    """Global attention flops (fwd) across layers."""
    if cfg.num_heads == 0:
        return 0.0
    H, hd, L = cfg.num_heads, cfg.resolved_head_dim, cfg.num_layers
    total = 0.0
    for layer in range(L):
        is_global = (not cfg.window) or (
            cfg.global_every and layer % cfg.global_every == 0)
        if S_cache is not None:  # decode
            vis = S_cache if is_global else min(cfg.window or S_cache, S_cache)
            total += 4.0 * B * vis * H * hd
        else:
            vis = S_q / 2 if is_global else min(cfg.window or S_q, S_q)
            total += 4.0 * B * S_q * vis * H * hd
    if cfg.family == "encdec" and S_cache is None:
        # encoder (bidir over enc_seq) + decoder cross-attention
        total += cfg.enc_layers * 4.0 * B * cfg.enc_seq * cfg.enc_seq \
            * H * hd / 1.0
        total += cfg.num_layers * 4.0 * B * S_q * cfg.enc_seq * H * hd
    if cfg.family == "encdec" and S_cache is not None:
        total += cfg.num_layers * 4.0 * B * cfg.enc_seq * H * hd
    return total


def _ssd_flops_fwd(cfg: ModelConfig, B, S):
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    nh, N, P, Q = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_chunk
    L = cfg.num_layers
    Q = min(Q, S)
    per_tok = nh * (2 * Q * N + 2 * Q * P + 6 * N * P) / 2
    return L * B * S * per_tok


def analytic_flops(cfg: ModelConfig, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    n_active = cfg.param_counts()["active"]
    if shape.kind == "decode":
        fwd = 2.0 * n_active * B + _attn_flops_fwd(cfg, B, 1, S_cache=S) \
            + _ssd_flops_fwd(cfg, B, 1)
        return fwd
    T = B * S
    fwd = 2.0 * n_active * T + _attn_flops_fwd(cfg, B, S) \
        + _ssd_flops_fwd(cfg, B, S)
    if shape.kind == "train":
        refwd = 1.0 if cfg.remat in ("full", "dots") else 0.0
        if cfg.remat_block and cfg.remat_block > 1:
            refwd += 1.0  # two-level: block refwd + per-layer refwd
        return fwd * (3.0 + refwd)
    return fwd  # prefill


def analytic_bytes(cfg: ModelConfig, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    counts = cfg.param_counts()
    pbytes = counts["total"] * 2.0  # bf16
    D, Fd, L, V = cfg.d_model, cfg.d_ff, cfg.num_layers, cfg.vocab_size
    F_eff = Fd * (cfg.top_k if cfg.family == "moe" else 1)
    if cfg.family == "moe":
        # params touched per step: attention etc. + routed experts actually
        # hit; at train batch sizes every expert is hit — full read.
        pass
    if shape.kind == "decode":
        cache = 0.0
        if cfg.num_heads:
            KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            for layer in range(L):
                is_global = (not cfg.window) or (
                    cfg.global_every and layer % cfg.global_every == 0)
                vis = S if is_global else min(cfg.window or S, S)
                cache += 2.0 * B * vis * KV * hd * 2
        if cfg.family in ("ssm", "hybrid"):
            cache += L * B * cfg.ssm_heads * cfg.ssm_state \
                * cfg.ssm_head_dim * 4
        # MoE decode touches <= B*top_k experts per layer
        if cfg.family == "moe":
            expert_bytes = 3 * D * Fd * 2
            touched = min(B * cfg.top_k, cfg.num_experts)
            pbytes = (counts["total"]
                      - cfg.num_experts * expert_bytes / 2 * L) * 2
            pbytes = counts["total"] * 2.0 \
                - L * (cfg.num_experts - touched) * expert_bytes
        act = L * B * (8 * D + 4 * F_eff) * 2
        return pbytes + cache + act
    T = B * S
    act_passes = 1.0
    if shape.kind == "train":
        act_passes = 3.0 + (1.0 if cfg.remat != "none" else 0.0)
    act = L * T * (8 * D + 4 * F_eff) * 2.0 * act_passes
    ce = T * V * 4.0 * (2 if shape.kind == "train" else 1) * 2
    if shape.kind == "train":
        opt = counts["total"] * (16.0 if cfg.optimizer == "adamw" else 1.0)
        return pbytes * 3 + counts["total"] * 2 * 2 + opt + act + ce
    return pbytes + act + ce


def load_cell(dryrun_dir: str, mesh_name: str, arch: str, shape: str):
    path = os.path.join(dryrun_dir, mesh_name, f"{arch}__{shape}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def roofline_row(dryrun_dir: str, mesh_name: str, arch: str,
                 shape_name: str) -> Dict:
    rec = load_cell(dryrun_dir, mesh_name, arch, shape_name)
    if rec is None:
        return None
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = rec["chips"]
    flops = analytic_flops(cfg, shape)
    bytes_ = analytic_bytes(cfg, shape)
    coll = rec["collective_bytes_global"]
    terms = {
        "compute_s": flops / (chips * PEAK_FLOPS),
        "memory_s": bytes_ / (chips * HBM_BW),
        "collective_s": coll / (chips * ICI_BW),
    }
    dominant = max(terms, key=terms.get)
    mf = rec["model_flops_global"]
    step_time = max(terms.values())
    mfu = mf / (step_time * chips * PEAK_FLOPS) if step_time > 0 else 0.0
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips,
        "flops_global": flops, "bytes_global": bytes_,
        "collective_bytes_global": coll,
        **{k: v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_fraction": mf / flops if flops else 0.0,
        "roofline_fraction_mfu": mfu,
        "temp_bytes_per_device":
            rec["memory_analysis"].get("temp_size_in_bytes", 0),
        "raw_cost_flops_per_device": rec["cost_per_device"]["flops"],
    }


def full_table(dryrun_dir: str = "experiments/dryrun"):
    from repro.configs import live_cells
    rows = []
    for mesh_name in ("pod16x16", "pod2x16x16"):
        for arch, shape in live_cells():
            r = roofline_row(dryrun_dir, mesh_name, arch, shape)
            if r:
                rows.append(r)
    return rows


# ---------------------------------------------------------------------------
# Streamed-fold roofline (the fused tile kernels, EXPERIMENTS §Kernels).
#
# The LM-stack roofline above prices a *hypothetical* TPU pod from spec
# sheets; the streamed k-center folds run on whatever backend the bench
# is on, so their denominator must be *measured*, not quoted: a STREAM-
# triad (a = b + s·c, 3 streams of traffic) gives the achievable memory
# bandwidth of this host/device, and each fold's achieved GB/s is
# reported as a fraction of that. A fold whose fraction approaches the
# triad's is bandwidth-bound — the fused one-pass claim — while a
# launch-/dispatch-bound fold would sit far below it AND fail the
# work-scaling test in kernel_bench.run_streamed.
# ---------------------------------------------------------------------------

def measured_peak_bw(n: int = 4_000_000, reps: int = 5) -> float:
    """Empirical streaming bandwidth (bytes/s) via a jitted f32 triad.

    Traffic model: read b, read c, write a = 3·4·n bytes per call. Best
    of ``reps`` (peak bandwidth wants the min time — interference only
    ever slows a run down).
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    b = jnp.arange(n, dtype=jnp.float32)
    c = jnp.ones((n,), jnp.float32)
    triad = jax.jit(lambda b, c: b + 1.5 * c)
    jax.block_until_ready(triad(b, c))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(triad(b, c))
        ts.append(time.perf_counter() - t0)
    return 3 * 4 * n / float(np.min(ts))
