"""Serving load generator: latency/QPS percentiles for ``KCenterService``.

Closed-loop (fixed client concurrency, each client waits for its answer
before sending the next) and open-loop (fixed arrival rate, async tickets)
drivers over the online k-center service, plus the insert-heavy ingest
micro-bench for ``stream_update``'s sequential tail (host-side O(b·new)
vs the legacy per-insertion device pass).

Recorded into ``BENCH_kcenter.json`` via ``benchmarks/run.py --only
serve``. The quick mode doubles as the CI smoke: it *asserts* the serving
contracts —

  * parity anchor: a served ``assign`` is bitwise ``ops.assign_nearest``
    on the snapshot centers;
  * p99 latency is finite under load (no stuck tickets);
  * batched QPS ≥ 5× the unbatched single-query baseline whenever the
    achieved mean batch is ≥ 32 rows (the continuous-batching win).
"""
from __future__ import annotations

import threading
import time
from typing import Iterator, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import stream_init, stream_update
from repro.data import gau
from repro.kernels import ops
from repro.serve import KCenterService


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _pcts_us(lat_s) -> Tuple[float, float, float, float]:
    """(mean, p50, p95, p99) of a latency sample, in microseconds."""
    a = np.asarray(lat_s, np.float64) * 1e6
    if a.size == 0:
        return (float("nan"),) * 4
    return (float(a.mean()), float(np.percentile(a, 50)),
            float(np.percentile(a, 95)), float(np.percentile(a, 99)))


def _bootstrap(k: int, d: int, n_boot: int, seed: int,
               **service_kw) -> Tuple[KCenterService, np.ndarray]:
    """Service with an ingested bootstrap set; returns (service, points).

    Clustered points (``data.gau``) so the doubling sketch actually
    retains a multi-center set — an isotropic blob collapses to one
    center, a degenerate service."""
    pts = gau(n_boot, k, d=d, seed=seed)
    svc = KCenterService(k, d, **service_kw)
    svc.submit_points(pts)
    svc.drain(timeout=120)
    return svc, pts


def closed_loop(svc: KCenterService, *, clients: int, duration_s: float,
                rows_per_req: int = 1, seed: int = 0):
    """Fixed-concurrency driver: each client thread sends one request,
    waits for the answer, repeats until the deadline. Returns
    ``(latencies_s, qps)`` over completed requests."""
    rng = np.random.default_rng(seed)
    qs = [rng.normal(size=(rows_per_req, svc._d)).astype(np.float32)
          for _ in range(clients)]
    lats: list = [[] for _ in range(clients)]
    start_gate = threading.Barrier(clients + 1)
    stop = threading.Event()

    def client(i: int) -> None:
        q = qs[i]
        out = lats[i]
        start_gate.wait()
        while not stop.is_set():
            t0 = time.monotonic()
            svc.assign(q, timeout=60)
            out.append(time.monotonic() - t0)

    threads = [threading.Thread(target=client, args=(i,), daemon=True)
               for i in range(clients)]
    for t in threads:
        t.start()
    start_gate.wait()
    t0 = time.monotonic()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    all_lats = [x for per in lats for x in per]
    return all_lats, len(all_lats) / wall


def open_loop(svc: KCenterService, *, rate_qps: float, duration_s: float,
              rows_per_req: int = 1, seed: int = 0):
    """Fixed-arrival-rate driver: submit async tickets on a pacing clock
    regardless of completions (the open-loop column of serving papers —
    it surfaces queueing delay a closed loop hides). Returns
    ``(latencies_s, achieved_qps)``."""
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(rows_per_req, svc._d)).astype(np.float32)
    period = 1.0 / rate_qps
    tickets = []
    t0 = time.monotonic()
    n = 0
    while True:
        now = time.monotonic()
        if now - t0 >= duration_s:
            break
        target = t0 + n * period
        if now < target:
            time.sleep(min(target - now, 0.001))
            continue
        tickets.append(svc.assign_async(q))
        n += 1
    for t in tickets:
        t.result(timeout=60)
    wall = time.monotonic() - t0
    lats = [t.t_done - t.t_submit for t in tickets]
    return lats, len(tickets) / wall


def ingest_tail_time(tail: str, *, n: int, k: int, d: int, batch: int,
                     seed: int = 0) -> Tuple[float, int]:
    """Wall seconds to sketch an insert-heavy stream with the given
    ``stream_update`` tail. Points arrive at growing scale so the radius
    keeps doubling — the regime where the legacy tail pays one device
    round-trip per inserted center. Returns ``(seconds, center_count)``."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, d)).astype(np.float32)
    pts *= np.linspace(1.0, 64.0, n, dtype=np.float32)[:, None]
    st = stream_init(k, d)
    t0 = time.perf_counter()
    for i in range(0, n, batch):
        st = stream_update(st, pts[i:i + batch], tail=tail)
    return time.perf_counter() - t0, st.count


# ---------------------------------------------------------------------------
# bench sections
# ---------------------------------------------------------------------------

def run(full: bool = False) -> Iterator[Tuple[str, float, str]]:
    """Yield ``(name, us_per_call, derived)`` rows; assert the serving
    smoke contracts (parity / finite p99 / ≥5× batching win)."""
    k, d = 16, 16
    clients = 64
    dur = 3.0 if full else 0.8
    rng = np.random.default_rng(7)

    # -- parity anchor (the CI smoke's correctness gate) -------------------
    svc, _ = _bootstrap(k, d, 4096, seed=1)
    q = rng.normal(size=(37, d)).astype(np.float32)
    epoch, centers, _ = svc.snapshot()
    res = svc.assign(q, timeout=60)
    ri, rd = ops.assign_nearest(jnp.asarray(q), jnp.asarray(centers))
    assert res.epoch == epoch
    assert np.array_equal(np.asarray(ri), res.idx), "served idx != offline"
    assert np.array_equal(np.asarray(rd), res.d2), "served d2 != offline"
    yield "serve_parity_anchor", 0, "bitwise=TRUE"

    # warmup: touch every query bucket once so the measured loops see the
    # steady state (zero new operand signatures)
    for b in (1, 8, 64, 256):
        svc.assign(rng.normal(size=(b, d)).astype(np.float32), timeout=60)

    # -- closed loop: batched vs unbatched single-query baseline ----------
    lat_b, qps_b = closed_loop(svc, clients=clients, duration_s=dur)
    st = svc.stats
    mean_batch = st["batched_rows"] / max(st["batches"], 1)
    mean_us, p50, p95, p99 = _pcts_us(lat_b)
    assert np.isfinite(p99), "batched p99 latency not finite"
    yield (f"serve_closed_batched_c{clients}", mean_us,
           f"qps={qps_b:.0f};p50={p50:.0f};p95={p95:.0f};p99={p99:.0f};"
           f"mean_batch={mean_batch:.1f}")
    svc.close()

    svc_u, _ = _bootstrap(k, d, 4096, seed=1, batching=False)
    svc_u.assign(rng.normal(size=(1, d)).astype(np.float32), timeout=60)
    lat_u, qps_u = closed_loop(svc_u, clients=clients, duration_s=dur)
    mean_us, p50, p95, p99 = _pcts_us(lat_u)
    assert np.isfinite(p99), "unbatched p99 latency not finite"
    yield (f"serve_closed_unbatched_c{clients}", mean_us,
           f"qps={qps_u:.0f};p50={p50:.0f};p95={p95:.0f};p99={p99:.0f}")
    svc_u.close()

    speedup = qps_b / max(qps_u, 1e-9)
    if mean_batch >= 32:
        assert speedup >= 5.0, (
            f"batched QPS only {speedup:.1f}x the single-query baseline "
            f"at mean batch {mean_batch:.1f}")
    yield ("serve_batch_speedup", 0,
           f"x{speedup:.1f};mean_batch={mean_batch:.1f};"
           f"qps_batched={qps_b:.0f};qps_unbatched={qps_u:.0f}")

    # -- closed loop with live ingest ------------------------------------
    svc_i, boot = _bootstrap(k, d, 4096, seed=1)
    svc_i.assign(rng.normal(size=(1, d)).astype(np.float32), timeout=60)
    stop_feed = threading.Event()
    # steady-state ingest: same cluster centers as the bootstrap (same
    # gau seed), so arriving points are overwhelmingly covered and epochs
    # stay rare by design
    feed_pool = gau(16_384, k, d=d, seed=1)

    def feeder() -> None:
        off = 0
        while not stop_feed.is_set():
            svc_i.submit_points(feed_pool[off:off + 512])
            off = (off + 512) % (feed_pool.shape[0] - 512)
            time.sleep(0.002)

    feed = threading.Thread(target=feeder, daemon=True)
    feed.start()
    lat_i, qps_i = closed_loop(svc_i, clients=clients, duration_s=dur)
    stop_feed.set()
    feed.join()
    svc_i.drain(timeout=120)
    st_i = svc_i.stats
    mean_us, p50, p95, p99 = _pcts_us(lat_i)
    assert np.isfinite(p99), "ingest-on p99 latency not finite"
    yield (f"serve_closed_ingest_on_c{clients}", mean_us,
           f"qps={qps_i:.0f};p50={p50:.0f};p95={p95:.0f};p99={p99:.0f};"
           f"epochs={st_i['epochs']};refreshes={st_i['cache_refreshes']}")
    svc_i.close()

    # -- open loop at half the measured batched capacity ------------------
    svc_o, _ = _bootstrap(k, d, 4096, seed=1)
    svc_o.assign(rng.normal(size=(1, d)).astype(np.float32), timeout=60)
    rate = max(qps_b * 0.3, 100.0)
    lat_o, qps_o = open_loop(svc_o, rate_qps=rate, duration_s=dur)
    mean_us, p50, p95, p99 = _pcts_us(lat_o)
    assert np.isfinite(p99), "open-loop p99 latency not finite"
    yield (f"serve_open_rate{rate:.0f}", mean_us,
           f"qps={qps_o:.0f};p50={p50:.0f};p95={p95:.0f};p99={p99:.0f}")
    svc_o.close()

    # -- ingest tail micro-bench (insert-heavy regime) --------------------
    n_ing = 40_000 if full else 4_000
    t_host, c_host = ingest_tail_time("host", n=n_ing, k=64, d=8, batch=512)
    t_dev, c_dev = ingest_tail_time("device", n=n_ing, k=64, d=8, batch=512)
    yield (f"serve_ingest_tail_host_n{n_ing}", t_host * 1e6,
           f"centers={c_host}")
    yield (f"serve_ingest_tail_device_n{n_ing}", t_dev * 1e6,
           f"centers={c_dev}")
    yield ("serve_ingest_tail_speedup", 0,
           f"x{t_dev / max(t_host, 1e-9):.1f}")


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(full=args.full):
        print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    main()
