"""(k,z)-center benchmark: the outlier objective's cost next to plain MRG.

Two questions, one contaminated GAU cloud (planted clusters + far
outliers at a fixed contamination rate):

  * **radius vs z** — sweeping the outlier budget through the true
    contamination count: the reported (k,z) radius should collapse to the
    cluster scale exactly when z reaches the planted contamination (below
    it, some outlier must be covered), while plain MRG is pinned at the
    contamination distance for every z;
  * **wall-clock vs plain** — the streamed weighted-coreset pipeline's
    overhead over plain streamed MRG on the same executor/blocking (the
    extra work is the per-block weight aggregation, the weighted combine,
    the O(coreset²) host solve, and the top-(z+1) radius fold).

Run: ``PYTHONPATH=src python -m benchmarks.outliers_bench [--full]``,
or via ``python -m benchmarks.run --only outliers``. Yields
benchmarks/run.py-style ``(name, us_per_call, derived)`` rows.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import HostStreamExecutor, kz_center, mrg
from repro.data import HostSource, gau


def _contaminated(n: int, z: int, k_prime: int = 25, spread: float = 1000.0,
                  seed: int = 0):
    """GAU clusters + z outliers *scattered* at the spread scale — mutually
    far apart, so no k' ≪ z centers can absorb them (a tight contamination
    cluster would just cost plain k-center one center)."""
    x = np.asarray(gau(n, k_prime, seed=seed), np.float32).copy()
    rng = np.random.default_rng(seed + 1)
    x[:z] = (rng.normal(size=(z, x.shape[1])) * spread).astype(np.float32)
    return x


def run(full: bool = False):
    n = 200_000 if full else 20_000
    k = 16
    z_true = n // 500                      # 0.2% contamination
    x = _contaminated(n, z_true)
    rows = -(-n // 50)
    ex = HostStreamExecutor(block_rows=rows)

    t0 = time.time()
    plain = mrg(HostSource(x), k, executor=ex)
    t_plain = time.time() - t0
    r_plain = float(np.sqrt(np.asarray(plain.radius2)))
    yield (f"outliers_plain_mrg_n{n}_k{k}", t_plain * 1e6,
           f"radius={r_plain:.4g}")

    for z in (0, z_true // 2, z_true, 2 * z_true):
        t0 = time.time()
        res = kz_center(HostSource(x), k, z, executor=ex)
        t_kz = time.time() - t0
        r = float(np.sqrt(np.asarray(res.radius2)))
        yield (f"outliers_kz_n{n}_k{k}_z{z}", t_kz * 1e6,
               f"radius={r:.4g};coreset={res.coreset_size};"
               f"rounds={res.rounds};vs_plain={t_kz / t_plain:.2f}x")
        if z >= z_true:
            # enough budget to exclude every planted outlier: the radius
            # must collapse to the cluster scale while plain MRG stays
            # pinned by the scattered contamination
            assert r < r_plain / 4.0, (z, r, r_plain)


def main(full: bool = False) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in run(full=full):
        print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale n")
    main(ap.parse_args().full)
