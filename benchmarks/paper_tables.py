"""Paper Tables 2-5: solution value over k, per data family, per algorithm.

Methodology mirrors §7.1 of the paper: parallel machines are *simulated* —
m = 50 machine-blocks; MRG round-1 time is the vmapped-block wall time
divided by m (equal block sizes ⇒ max ≈ mean), round-2 runs on one
machine. Runtimes land in runtime_scaling.py; this module reports solution
values (covering radii).

Default sizes are paper-scale/10 (single CPU core); ``--full`` restores
the paper's n. Three graphs per (family, size), two runs each, averaged —
exactly the paper's 6-results protocol.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core import eim, gonzalez, mrg_sim
from repro.data import gau, kddlike, pokerlike, unb, unif

K_GRID = [2, 5, 10, 25, 50, 100]
M = 50  # machines, fixed as in the paper


def _value(points: np.ndarray, k: int, algo: str, seed: int = 0,
           phi: float = 8.0):
    pts = jnp.asarray(points)
    if algo == "gon":
        r = gonzalez(pts, k)
        return float(jnp.sqrt(r.radius2))
    if algo == "mrg":
        r = mrg_sim(pts, k, m=M, capacity=max(2 * k * M, points.shape[0] // M))
        return float(jnp.sqrt(r.radius2))
    if algo == "eim":
        r = eim(pts, k, jax.random.PRNGKey(seed), phi=phi)
        return float(jnp.sqrt(r.radius2))
    raise ValueError(algo)


def table(family: str, n: int, k_prime: int = 25, *, graphs: int = 3,
          runs: int = 2, k_grid=None, algos=("mrg", "eim", "gon")):
    """Returns {k: {algo: mean_value}} — one paper table."""
    gen = {"gau": lambda s: gau(n, k_prime, seed=s),
           "unif": lambda s: unif(n, seed=s),
           "unb": lambda s: unb(n, k_prime, seed=s),
           "kddlike": lambda s: kddlike(n, seed=s),
           "pokerlike": lambda s: pokerlike(n, seed=s)}[family]
    out = {}
    for k in (k_grid or K_GRID):
        vals = {a: [] for a in algos}
        for g in range(graphs):
            pts = gen(g)
            for r in range(runs):
                for a in algos:
                    vals[a].append(_value(pts, k, a, seed=g * 10 + r))
        out[k] = {a: float(np.mean(v)) for a, v in vals.items()}
    return out


def run(full: bool = False, quick: bool = False):
    """Tables 2-5 (+ real-data proxies). Yields (table_name, k, algo, value)."""
    scale = 1 if full else 10
    plan = [
        ("table2_gau", "gau", 1_000_000 // scale),
        ("table3_unif", "unif", 100_000 // scale),
        ("table4_unb", "unb", 200_000 // scale),
        ("table5_pokerlike", "pokerlike", 25_010 // (1 if full else 2)),
        ("fig1_kddlike", "kddlike", 400_000 // scale),
    ]
    kg = [2, 10, 25, 100] if quick else None
    graphs, runs = (1, 1) if quick else (3, 2)
    for name, family, n in plan:
        t = table(family, n, graphs=graphs, runs=runs, k_grid=kg)
        for k, row in t.items():
            for algo, v in row.items():
                yield name, n, k, algo, v
