"""Render EXPERIMENTS.md tables from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.emit_tables \
        --final experiments/dryrun_final --old experiments/dryrun_old

Splices the §Roofline table and the baseline→final per-cell delta table
into EXPERIMENTS.md at the <!-- ROOFLINE_TABLE --> / <!-- PERF_DELTA_TABLE -->
markers.
"""
from __future__ import annotations

import argparse
import json
import os


def _rows(dryrun_dir):
    from .roofline import full_table
    return full_table(dryrun_dir)


def roofline_md(dryrun_dir: str) -> str:
    rows = _rows(dryrun_dir)
    out = ["| mesh | arch | shape | dominant | mfu | compute_s | memory_s "
           "| collective_s | useful | temp GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['mesh']} | {r['arch']} | {r['shape']} | "
            f"{r['dominant'][:-2]} | {r['roofline_fraction_mfu']:.3f} | "
            f"{r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | {r['useful_fraction']:.2f} | "
            f"{r['temp_bytes_per_device']/2**30:.1f} |")
    return "\n".join(out)


def delta_md(old_dir: str, new_dir: str) -> str:
    out = ["| mesh | arch | shape | temp GiB old→new | coll GiB/dev old→new |",
           "|---|---|---|---|---|"]
    for mesh in ("pod16x16", "pod2x16x16"):
        od = os.path.join(old_dir, mesh)
        nd = os.path.join(new_dir, mesh)
        if not (os.path.isdir(od) and os.path.isdir(nd)):
            continue
        for f in sorted(os.listdir(nd)):
            if not f.endswith(".json") or not os.path.exists(
                    os.path.join(od, f)):
                continue
            o = json.load(open(os.path.join(od, f)))
            n = json.load(open(os.path.join(nd, f)))
            to = o["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
            tn = n["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
            co = o["collectives_per_device"]["operand_bytes"] / 2**30
            cn = n["collectives_per_device"]["operand_bytes"] / 2**30
            out.append(f"| {mesh} | {n['arch']} | {n['shape']} | "
                       f"{to:.1f} → {tn:.1f} | {co:.2f} → {cn:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--final", default="experiments/dryrun_final")
    ap.add_argument("--old", default="experiments/dryrun_old")
    ap.add_argument("--doc", default="EXPERIMENTS.md")
    args = ap.parse_args()

    doc = open(args.doc).read()
    doc = doc.replace("<!-- ROOFLINE_TABLE -->",
                      roofline_md(args.final))
    doc = doc.replace("<!-- PERF_DELTA_TABLE -->",
                      delta_md(args.old, args.final))
    open(args.doc, "w").write(doc)
    print("EXPERIMENTS.md tables written")


if __name__ == "__main__":
    main()
