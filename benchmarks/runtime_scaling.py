"""Paper Figures 2-4 (+ Table 1 asymptotics): runtime over k and over n.

Timing methodology = paper §7.1: machines are simulated; a MapReduce
round's time is the longest simulated machine's time. Concretely:

  GON   : wall time of the jitted sequential algorithm.
  MRG   : round-1 = wall(vmapped per-block GON) / m  (equal blocks ⇒
          max ≈ mean ⇒ total/m), round-2 = wall(GON on the k·m centers).
  EIM   : instrumented host loop (same jitted kernels as repro.core.eim,
          stepped round by round): rounds 1 & 3 are parallel over m
          (divide by m), round 2 (Select) and the final GON run on one
          machine. φ parameterizes Select exactly as Algorithm 3.

Everything is run twice and averaged; first call is a discarded warmup
(jit compile time is not a MapReduce cost).
"""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gonzalez
from repro.core.eim import _expected_caps
from repro.core.gonzalez import covering_radius
from repro.data import gau
from repro.kernels import ops

M = 50
_BIG = jnp.float32(3.4e38)
_NEG = jnp.float32(-3.4e38)


def _timer(fn, *args, reps: int = 2):
    fn(*args)  # warmup/compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts))


# --------------------------------------------------------------------------
# GON / MRG timing
# --------------------------------------------------------------------------

def time_gon(points, k: int) -> float:
    pts = jnp.asarray(points)
    return _timer(lambda p: gonzalez(p, k).radius2, pts)


def time_mrg(points, k: int, m: int = M):
    """(simulated-parallel time, value)."""
    from repro.core.mrg import _block, _mrg_round
    pts = jnp.asarray(points)
    blocked, mask = _block(pts, m)
    t_r1 = _timer(lambda b, mk: _mrg_round(b, mk, k, m, "auto")[0],
                  blocked, mask) / m
    centers, valid = _mrg_round(blocked, mask, k, m, "auto")
    t_r2 = _timer(lambda c, v: gonzalez(c, k, mask=v).radius2,
                  centers, valid)
    final = gonzalez(centers, k, mask=valid)
    val = float(covering_radius(pts, final.centers))
    return t_r1 + t_r2, val


# --------------------------------------------------------------------------
# EIM: instrumented host loop (one jitted kernel per MapReduce round)
# --------------------------------------------------------------------------

def _eim_rounds(n: int, k: int, eps: float):
    ln_n = math.log(max(n, 2))
    threshold = (4.0 / eps) * k * (n ** eps) * ln_n
    s_cap, h_cap = _expected_caps(n, k, eps)
    return ln_n, threshold, s_cap, h_cap


def time_eim_stream(points, k: int, *, eps: float = 0.1, phi: float = 8.0,
                    seed: int = 0, max_iters: int = 64,
                    compact_threshold: float = 0.5,
                    block_rows: int | None = None, reps: int = 2):
    """Production streamed EIM (§Perf cell C) — ``repro.core.eim`` over a
    ``HostSource`` on ``HostStreamExecutor``, wall-clocked end to end.

    ``compact_threshold=0`` is the fixed-shape baseline (every fold pass
    touches all n rows, T times); ``compact_threshold=1`` compacts the
    relation into an ``IndexedSource`` view after every shrinking
    iteration — the paper's own O(|R_l|·|S_new|/m) Round-3 charge realized
    in the shipped algorithm. (A host-side prototype of this trick used to
    live here as ``time_eim_compact``; it graduated into ``core/eim.py``
    and this now times the production path.) The sampled sets — and hence
    the returned value and iteration count — are bitwise invariant to the
    knob. Returns (time, value, iters).
    """
    from repro.core import HostStreamExecutor
    from repro.core.eim import eim
    from repro.data import HostSource

    x = np.asarray(points, np.float32)
    key = jax.random.PRNGKey(seed)

    def run():
        return eim(HostSource(x), k, key, eps=eps, phi=phi,
                   max_iters=max_iters,
                   executor=HostStreamExecutor(block_rows=block_rows),
                   compact_threshold=compact_threshold)

    res = run()                  # warmup: the loop trajectory is
    jax.block_until_ready(res.centers)   # deterministic, so this compiles
    ts = []                              # every block shape the timed
    for _ in range(reps):                # reps will see
        t0 = time.perf_counter()
        res = run()
        jax.block_until_ready(res.centers)
        ts.append(time.perf_counter() - t0)
    val = float(np.sqrt(np.float32(res.radius2)))
    return float(np.mean(ts)), val, int(res.sample.iters)


def time_eim(points, k: int, *, eps: float = 0.1, phi: float = 8.0,
             m: int = M, seed: int = 0, max_iters: int = 64):
    """(simulated-parallel time, value, iterations)."""
    pts = jnp.asarray(points, jnp.float32)
    n, d = pts.shape
    ln_n, threshold, s_cap, h_cap = _eim_rounds(n, k, eps)
    rank = max(1, min(h_cap, int(round(phi * ln_n))))

    @jax.jit
    def round1(key, r_mask):
        r_size = jnp.sum(r_mask).astype(jnp.float32)
        k_s, k_h = jax.random.split(key)
        p_s = jnp.minimum(9.0 * k * (n ** eps) * ln_n / r_size, 1.0)
        p_h = jnp.minimum(4.0 * (n ** eps) * ln_n / r_size, 1.0)
        # same counter-based per-row sampler as repro.core.eim
        new_s = ops.bernoulli_rows(k_s, 0, n, p_s) & r_mask
        h_mask = ops.bernoulli_rows(k_h, 0, n, p_h) & r_mask
        return new_s, h_mask

    @jax.jit
    def round3_update(d_s, new_s):
        s_idx = jnp.nonzero(new_s, size=s_cap, fill_value=n)[0]
        s_valid = s_idx < n
        s_pts = pts[jnp.minimum(s_idx, n - 1)]
        d_new = ops.pairwise_dist2(pts, s_pts)
        d_new = jnp.where(s_valid[None, :], d_new, _BIG)
        return jnp.minimum(d_s, jnp.min(d_new, axis=1))

    @jax.jit
    def round2_select(d_s, h_mask):
        d_h = jnp.where(h_mask, d_s, _NEG)
        top = jax.lax.top_k(d_h, rank)[0]
        pivot = top[rank - 1]
        return jnp.where(pivot <= _NEG / 2, -1.0, pivot)

    @jax.jit
    def round3_filter(r_mask, new_s, d_s, pivot):
        r = r_mask & ~new_s
        return r & ~(d_s <= pivot)

    key = jax.random.PRNGKey(seed)
    r_mask = jnp.ones((n,), bool)
    s_mask = jnp.zeros((n,), bool)
    d_s = jnp.full((n,), _BIG)
    t_par, t_seq = 0.0, 0.0
    iters = 0
    # warmup compiles
    round1(key, r_mask)
    round3_update(d_s, s_mask)
    round2_select(d_s, r_mask)
    round3_filter(r_mask, s_mask, d_s, jnp.float32(-1))

    while int(jnp.sum(r_mask)) > threshold and iters < max_iters:
        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        new_s, h_mask = jax.block_until_ready(round1(sub, r_mask))
        t_par += (time.perf_counter() - t0) / m
        t0 = time.perf_counter()
        d_s = jax.block_until_ready(round3_update(d_s, new_s))
        t_par += (time.perf_counter() - t0) / m
        t0 = time.perf_counter()
        pivot = jax.block_until_ready(round2_select(d_s, h_mask))
        t_seq += time.perf_counter() - t0
        t0 = time.perf_counter()
        r_mask = jax.block_until_ready(
            round3_filter(r_mask, new_s, d_s, pivot))
        t_par += (time.perf_counter() - t0) / m
        s_mask = s_mask | new_s
        iters += 1

    sample = r_mask | s_mask
    t0 = time.perf_counter()
    res = jax.block_until_ready(gonzalez(pts, k, mask=sample))
    t_seq += time.perf_counter() - t0
    val = float(covering_radius(pts, res.centers))
    return t_par + t_seq, val, iters


# --------------------------------------------------------------------------
# Figures
# --------------------------------------------------------------------------

def fig_runtime_over_k(n: int = 100_000, family: str = "gau",
                       k_grid=(2, 5, 10, 25, 50, 100), seed: int = 0):
    """Fig 2/3: runtime vs k at fixed n. Yields (k, algo, seconds, value)."""
    from repro.data import unif
    pts = gau(n, 25, seed=seed) if family == "gau" else unif(n, seed=seed)
    for k in k_grid:
        t_g = time_gon(pts, k)
        v_g = float(jnp.sqrt(gonzalez(jnp.asarray(pts), k).radius2))
        t_m, v_m = time_mrg(pts, k)
        t_e, v_e, it = time_eim(pts, k)
        yield k, "gon", t_g, v_g
        yield k, "mrg", t_m, v_m
        yield k, "eim", t_e, v_e


def fig_runtime_over_n(k: int = 25, family: str = "gau",
                       n_grid=(10_000, 50_000, 100_000, 500_000, 1_000_000),
                       seed: int = 0):
    """Fig 4: runtime vs n at fixed k."""
    for n in n_grid:
        pts = gau(n, 25, seed=seed)
        yield n, "gon", time_gon(pts, k)
        yield n, "mrg", time_mrg(pts, k)[0]
        yield n, "eim", time_eim(pts, k)[0]


def table1_asymptotics(seed: int = 0):
    """Empirical check of Table 1: fit runtime ~ k and ~ n exponents for
    the dominant rounds."""
    ks = np.array([5, 10, 20, 40, 80])
    n = 200_000
    pts = gau(n, 25, seed=seed)
    t_gon = np.array([time_gon(pts, int(k)) for k in ks])
    slope_k = np.polyfit(np.log(ks), np.log(t_gon), 1)[0]
    ns = np.array([25_000, 50_000, 100_000, 200_000])
    t_n = np.array([time_gon(gau(int(nn), 25, seed=seed), 25)
                    for nn in ns])
    slope_n = np.polyfit(np.log(ns), np.log(t_n), 1)[0]
    return {"gon_k_exponent": float(slope_k),
            "gon_n_exponent": float(slope_n)}
