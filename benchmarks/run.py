"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure + the kernel microbench + the roofline
table (the latter reads the dry-run artifacts if present). Prints
``name,us_per_call,derived`` CSV as required; ``--json PATH`` additionally
writes the same rows as a JSON list (the ``BENCH_kcenter.json`` perf
trajectory — CI uploads it as a per-PR artifact).

Default is quick mode (paper sizes / 10, fewer repeats) so the suite
finishes on one CPU core; ``--full`` restores paper-scale sizes, ``--deep``
adds the full k×φ grids.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale n")
    ap.add_argument("--deep", action="store_true", help="full k/φ grids")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a JSON list (the "
                         "BENCH_kcenter.json trajectory artifact)")
    ap.add_argument("--only", default=None,
                    help="comma list: tables,runtime,phi,perfcell,kernels,"
                         "streamedkernels,chunked,serve,outliers,roofline"
                         " (+ cluster — opt-in only: spawns real"
                         " multi-process jax.distributed workers)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    rows: list[dict] = []

    def emit(name: str, us: float, derived: str) -> None:
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": derived})
        print(f"{name},{us:.0f},{derived}", flush=True)

    print("name,us_per_call,derived")
    t_start = time.time()

    if want("tables"):
        from . import paper_tables
        for name, n, k, algo, v in paper_tables.run(full=args.full,
                                                    quick=not args.deep):
            emit(f"{name}_n{n}_k{k}_{algo}", 0, f"value={v:.4g}")

    if want("runtime"):
        from . import runtime_scaling
        n = 100_000 if args.full else 20_000
        kg = (2, 10, 25, 100) if not args.deep else (2, 5, 10, 25, 50, 100)
        for k, algo, t, v in runtime_scaling.fig_runtime_over_k(
                n=n, k_grid=kg):
            emit(f"fig2_runtime_k{k}_{algo}", t * 1e6, f"value={v:.4g}")
        ngrid = ((10_000, 100_000, 1_000_000) if args.full
                 else (5_000, 20_000, 50_000))
        for n_, algo, t in runtime_scaling.fig_runtime_over_n(
                k=25, n_grid=ngrid):
            emit(f"fig4_runtime_n{n_}_{algo}", t * 1e6, "")
        asym = runtime_scaling.table1_asymptotics()
        for k_, v_ in asym.items():
            emit(f"table1_{k_}", 0, f"exponent={v_:.3f}")

    if want("phi"):
        from . import phi_sweep
        # quick sizes chosen so the sampling loop actually engages
        # (threshold (4/ε)k·n^ε·ln n < n) for the k grid used
        n = 200_000 if args.full else 50_000
        kg = None if args.deep else (10, 25)
        for k, phi, v, t, it in phi_sweep.run(n=n, k_grid=kg,
                                              graphs=1 if not args.deep else 3,
                                              runs=1 if not args.deep else 2):
            emit(f"table6_7_phi{phi:g}_k{k}", t * 1e6,
                 f"value={v:.4g};iters={it:.1f}")

    if want("perfcell"):
        # §Perf cell C: fixed-shape streamed EIM vs the compacted-R
        # production path (compact_threshold graduated from the old
        # host-side prototype into core/eim.py). k/φ are chosen so the
        # Select filter engages at ε=0.05 (rank=φ·ln n must not exceed
        # E|H|=4·n^ε·ln n, and n^ε<2 here), giving the paper's geometric
        # |R| shrink; both rows are the *same* production algorithm — the
        # sample is bitwise invariant to the knob.
        from repro.data import gau

        from .runtime_scaling import time_eim_stream
        n = 200_000 if args.full else 100_000
        pts = gau(n, 25, seed=0)
        t1, v1, i1 = time_eim_stream(pts, 4, eps=0.05, phi=5.0,
                                     compact_threshold=0.0)
        t2, v2, i2 = time_eim_stream(pts, 4, eps=0.05, phi=5.0,
                                     compact_threshold=1.0)
        emit(f"perfC_eim_baseline_n{n}", t1 * 1e6, f"value={v1:.4g};iters={i1}")
        emit(f"perfC_eim_compact_n{n}", t2 * 1e6,
             f"value={v2:.4g};iters={i2};speedup={t1/t2:.2f}x")

    if want("kernels"):
        from . import kernel_bench
        for name, us, derived in kernel_bench.run():
            emit(name, us, derived)

    if want("streamedkernels"):
        from . import kernel_bench
        for name, us, derived in kernel_bench.run_streamed(full=args.full):
            emit(name, us, derived)

    if want("chunked"):
        from . import chunked_scaling
        for name, us, derived in chunked_scaling.run(full=args.full):
            emit(name, us, derived)

    if want("serve"):
        from . import serve_bench
        for name, us, derived in serve_bench.run(full=args.full):
            emit(name, us, derived)

    if want("outliers"):
        from . import outliers_bench
        for name, us, derived in outliers_bench.run(full=args.full):
            emit(name, us, derived)

    # opt-in only (never part of the default sweep): real worker
    # processes + a localhost coordinator per row
    if only is not None and "cluster" in only:
        from . import cluster_bench
        for name, us, derived in cluster_bench.run(full=args.full):
            emit(name, us, derived)

    if want("roofline"):
        import os

        from . import roofline
        d = "experiments/dryrun_final" \
            if os.path.isdir("experiments/dryrun_final") \
            else "experiments/dryrun"
        rows_r = roofline.full_table(d)
        for r in rows_r:
            emit(f"roofline_{r['mesh']}_{r['arch']}_{r['shape']}", 0,
                 f"dom={r['dominant'][:-2]};mfu={r['roofline_fraction_mfu']:.3f};"
                 f"comp={r['compute_s']:.3e};mem={r['memory_s']:.3e};"
                 f"coll={r['collective_s']:.3e}")
        if not rows_r:
            emit("roofline_missing", 0, "run repro.launch.dryrun first")

    emit("total_wall", (time.time() - t_start) * 1e6,
         f"seconds={time.time() - t_start:.1f}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {len(rows)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
