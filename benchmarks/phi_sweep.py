"""Paper Tables 6-7: EIM value & runtime over the φ parameter.

GAU, n = 200,000 (paper-scale; ``--quick`` divides by 10), k' = 25,
φ ∈ {1, 4, 6, 8}. φ = 8 is the original Ene-et-al. scheme; 5.15 is the
paper's provable-bound threshold — values below it trade the w.s.p.
10-approximation for speed (paper §8.3 observes they are often *better*,
because sampling fewer points avoids cluster-perimeter centers).

This sweep is folded into ``benchmarks/run.py`` (the ``phi`` section), so
the φ value/runtime trade-off lands in the ``BENCH_kcenter.json`` CI
artifact alongside the MRG rows. The timing harness
(``runtime_scaling.time_eim``) draws from the same counter-based per-row
sampler as ``repro.core.eim``, so the measured Round-1 cost is the
production sampler's. Out-of-core φ runs (n past the device budget) are
the EIM section of ``benchmarks/chunked_scaling.py``.
"""
from __future__ import annotations

import numpy as np

from repro.data import gau

from .runtime_scaling import time_eim

PHI_GRID = [1.0, 4.0, 6.0, 8.0]
K_GRID = [2, 5, 10, 25, 50, 100]


def run(n: int = 200_000, k_prime: int = 25, *, graphs: int = 3,
        runs: int = 2, k_grid=None, phi_grid=None):
    """Yields (k, phi, mean_value, mean_seconds, mean_iters)."""
    for k in (k_grid or K_GRID):
        for phi in (phi_grid or PHI_GRID):
            vals, times, its = [], [], []
            for g in range(graphs):
                pts = gau(n, k_prime, seed=g)
                for r in range(runs):
                    t, v, it = time_eim(pts, k, phi=phi, seed=g * 10 + r)
                    vals.append(v)
                    times.append(t)
                    its.append(it)
            yield (k, phi, float(np.mean(vals)), float(np.mean(times)),
                   float(np.mean(its)))
