"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp reference.

On this container the Pallas kernels execute in interpret mode, so the
*performance* numbers that matter are the ref-path (XLA-fused) timings and
the kernels' structural properties (VMEM working set per BlockSpec tile);
the interpret runs validate numerics only. Derived column reports achieved
GFLOP/s of the reference path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _t(fn, *args, reps=3):
    fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run():
    """Yields (name, us_per_call, derived)."""
    rng = np.random.default_rng(0)
    for n, m, d in [(100_000, 256, 2), (100_000, 256, 64),
                    (20_000, 1024, 128)]:
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        md = jnp.asarray(rng.uniform(1, 9, size=(n,)).astype(np.float32))
        t = _t(lambda a, b: ops.pairwise_dist2(a, b, impl="ref"), x, c)
        gflops = 2 * n * m * d / t / 1e9
        yield f"pairwise_dist2_n{n}_m{m}_d{d}", t * 1e6, f"{gflops:.1f}GFLOP/s"
        t = _t(lambda a, b, mm: ops.fused_min_argmax(a, b, mm, impl="ref"),
               x, c[0], md)
        gbs = (n * d * 4 + n * 8) / t / 1e9
        yield f"fused_min_argmax_n{n}_d{d}", t * 1e6, f"{gbs:.1f}GB/s"
        t = _t(lambda a, b: ops.assign_nearest(a, b, impl="ref"), x, c)
        yield f"assign_nearest_n{n}_m{m}_d{d}", t * 1e6, \
            f"{2 * n * m * d / t / 1e9:.1f}GFLOP/s"
    # VMEM working sets for the documented BlockSpecs (structural check)
    from repro.kernels.pairwise import DEFAULT_BM, DEFAULT_BN
    for d in (64, 1024, 4096):
        ws = (DEFAULT_BN + DEFAULT_BM) * d * 4 + DEFAULT_BN * DEFAULT_BM * 4
        yield f"pairwise_vmem_ws_d{d}", 0.0, f"{ws / 2 ** 20:.1f}MiB<16MiB"


def _stream_bytes(n: int, d: int) -> int:
    """HBM traffic model of one fused filter block: read the (n, d) tile +
    carried d_s + H mask, write d_s — the O(m)/O(rank) outputs and the
    resident (m, d) centers are noise at these shapes."""
    return 4 * n * (d + 3)


def run_streamed(full: bool = False):
    """Streamed-fold section: the fused one-pass filter block vs the
    multi-dispatch ``lax.scan`` reference path, as achieved GB/s against
    the *measured* triad roofline (benchmarks.roofline.measured_peak_bw).

    Both rows run the same block share of EIM Rounds 2–3 at the same tile
    size, so the delta isolates exactly what the tentpole fuses:

    * "scan" — eager ``filter_tile_update(impl="ref", chunk=…)``: the form
      the ref source folds execute per block — a ``lax.scan`` of distance
      tiles followed by separate min / where / top-k dispatches with the
      reduced vectors (and per-step distance blocks) materialized between
      them.
    * "fused" — the jitted one-program ``engine.eim_filter_block`` the
      executors dispatch, at ``impl="auto"``: the native Pallas tile on
      TPU/feature-detected GPU; on CPU it resolves to the single fused XLA
      program (interpret-mode timings would be meaningless), which still
      buys the dispatch fusion the kernel provides natively.

    Also yields a launch-bound canary: a bandwidth-bound fold must scale
    ~linearly in n, so t(n)/t(n/4) far below 4 would mean per-call
    overhead, not HBM traffic, dominates.
    """
    from repro.kernels import engine

    from . import roofline

    peak = roofline.measured_peak_bw()
    yield "streamfold_triad_peak", 0.0, f"{peak / 1e9:.1f}GB/s"

    rng = np.random.default_rng(1)
    rank = 16
    chunk = 2048
    shapes = [(400_000, 64, 8), (200_000, 256, 32)]
    if full:
        shapes.append((1_000_000, 256, 64))
    for n, m, d in shapes:
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        ds = jnp.full((n,), 3.4e38, jnp.float32)
        h = jnp.ones((n,), bool)
        top = engine.top_k_init(rank)
        bytes_ = _stream_bytes(n, d)

        def fused(blk, ds_):
            return engine.eim_filter_block(blk, c, ds_, h[: blk.shape[0]],
                                           top, rank=rank, impl="auto",
                                           chunk=chunk)

        def scan(blk, ds_):
            return engine.filter_tile_update(blk, c, ds_, h[: blk.shape[0]],
                                             rank=rank, impl="ref",
                                             chunk=chunk)

        t_f = _t(fused, x, ds, reps=5)
        t_s = _t(scan, x, ds, reps=5)
        g_f, g_s = bytes_ / t_f / 1e9, bytes_ / t_s / 1e9
        yield (f"streamfold_fused_n{n}_m{m}_d{d}", t_f * 1e6,
               f"{g_f:.1f}GB/s;roofline={g_f * 1e9 / peak:.2f}")
        yield (f"streamfold_scan_n{n}_m{m}_d{d}", t_s * 1e6,
               f"{g_s:.1f}GB/s;roofline={g_s * 1e9 / peak:.2f}")
        yield (f"streamfold_speedup_n{n}_m{m}_d{d}", 0.0,
               f"fused/scan={t_s / t_f:.2f}x")
        # Launch-bound canary: quarter the work, expect ≥1.5× less time.
        t_q = _t(lambda: fused(x[: n // 4], ds[: n // 4]), reps=5)
        yield (f"streamfold_workscale_n{n}_m{m}_d{d}", t_q * 1e6,
               f"t(n)/t(n/4)={t_f / t_q:.2f};bw_bound={t_f / t_q > 1.5}")
