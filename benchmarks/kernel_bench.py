"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp reference.

On this container the Pallas kernels execute in interpret mode, so the
*performance* numbers that matter are the ref-path (XLA-fused) timings and
the kernels' structural properties (VMEM working set per BlockSpec tile);
the interpret runs validate numerics only. Derived column reports achieved
GFLOP/s of the reference path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _t(fn, *args, reps=3):
    fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run():
    """Yields (name, us_per_call, derived)."""
    rng = np.random.default_rng(0)
    for n, m, d in [(100_000, 256, 2), (100_000, 256, 64),
                    (20_000, 1024, 128)]:
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        md = jnp.asarray(rng.uniform(1, 9, size=(n,)).astype(np.float32))
        t = _t(lambda a, b: ops.pairwise_dist2(a, b, impl="ref"), x, c)
        gflops = 2 * n * m * d / t / 1e9
        yield f"pairwise_dist2_n{n}_m{m}_d{d}", t * 1e6, f"{gflops:.1f}GFLOP/s"
        t = _t(lambda a, b, mm: ops.fused_min_argmax(a, b, mm, impl="ref"),
               x, c[0], md)
        gbs = (n * d * 4 + n * 8) / t / 1e9
        yield f"fused_min_argmax_n{n}_d{d}", t * 1e6, f"{gbs:.1f}GB/s"
        t = _t(lambda a, b: ops.assign_nearest(a, b, impl="ref"), x, c)
        yield f"assign_nearest_n{n}_m{m}_d{d}", t * 1e6, \
            f"{2 * n * m * d / t / 1e9:.1f}GFLOP/s"
    # VMEM working sets for the documented BlockSpecs (structural check)
    from repro.kernels.pairwise import DEFAULT_BM, DEFAULT_BN
    for d in (64, 1024, 4096):
        ws = (DEFAULT_BN + DEFAULT_BM) * d * 4 + DEFAULT_BN * DEFAULT_BM * 4
        yield f"pairwise_vmem_ws_d{d}", 0.0, f"{ws / 2 ** 20:.1f}MiB<16MiB"
